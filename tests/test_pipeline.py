"""Memory-pipeline invariants: stage bypass, fused == unfused, full-budget
sparse == dense, placement policy, profiler attribution."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.core.pipeline import MemoryPipeline, StageProfiler
from repro.core import placement
from repro.core.methods import dsa, seer, lserve, get_sparse_method
from repro.models import init_params, prefill, decode_step

TP = 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("llama3.2-1b").smoke()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, tp=TP)
    B, S = 2, 64
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    _, caches = jax.jit(lambda p, t: prefill(p, cfg, t, max_len=S + 8, tp=TP))(
        params, toks)
    dense_logits, _ = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c, tp=TP))(
        params, toks[:, 0], caches)
    return cfg, params, toks, caches, dense_logits


def test_stage_bypass_is_identity():
    """§3.1: a skipped stage costs nothing and passes data through."""
    pipe = MemoryPipeline("id-test", prepare=None, relevancy=None,
                          retrieve=None, apply=lambda Mp, x: Mp + x)
    out = pipe.run(jnp.asarray(2.0), jnp.asarray(3.0))
    assert float(out) == 5.0
    # fully-empty pipeline returns the memory untouched
    pipe2 = MemoryPipeline("empty")
    assert float(pipe2.run(jnp.asarray(7.0), None)) == 7.0


@pytest.mark.parametrize("method", ["dsa", "seer", "lserve"])
def test_full_budget_sparse_equals_dense(setup, method):
    """When the budget covers the whole context, the sparse pipeline must be
    EXACTLY dense attention (retrieval selects everything)."""
    cfg, params, toks, caches, dense_logits = setup
    mem = cfg.memory.replace(method=method, top_k=128, token_budget=128,
                             selection="topk", min_context=0)
    init_fn, mk = get_sparse_method(method)
    sp = init_fn(jax.random.PRNGKey(7), cfg, mem)
    kw = {"page": 8} if method == "dsa" else {}
    sfn = mk(cfg, mem, tp=TP, **kw)
    logits, _ = jax.jit(lambda p, t, c, s: decode_step(
        p, cfg, t, c, tp=TP, sparse_fn=sfn, sparse_params=s))(
        params, toks[:, 0], caches, sp)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(dense_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fused_equals_unfused_pipeline(setup):
    """Pallas-fused relevancy+retrieval == XLA unfused (paper Fig. 9 setup)."""
    cfg, params, toks, caches, _ = setup
    mem = cfg.memory.replace(method="dsa", top_k=32)
    sp_all = dsa.dsa_init(jax.random.PRNGKey(9), cfg, mem)
    sp = jax.tree.map(lambda a: a[0], sp_all)
    kc, vc = caches["k"][0], caches["v"][0]
    B = kc.shape[0]
    q = jax.random.normal(jax.random.PRNGKey(2),
                          (B, 1, cfg.padded_heads(TP), cfg.hd), jnp.float32)
    M = (kc, vc)
    out_u = dsa.build_pipeline(cfg, mem, sp, page=8, fused=False).run(M, q)
    out_f = dsa.build_pipeline(cfg, mem, sp, page=8, fused=True).run(M, q)
    np.testing.assert_allclose(np.asarray(out_u), np.asarray(out_f),
                               rtol=1e-4, atol=1e-4)


def test_threshold_mode_subset_of_topk(setup):
    """Seer threshold retrieval only ever drops blocks vs top-k mode."""
    cfg, params, toks, caches, dense_logits = setup
    base = cfg.memory.replace(method="seer", token_budget=32, block_size=8,
                              min_context=0)
    init_fn, mk = get_sparse_method("seer")
    sp = init_fn(jax.random.PRNGKey(7), cfg, base)
    step = lambda mem: jax.jit(lambda p, t, c, s: decode_step(
        p, cfg, t, c, tp=TP, sparse_fn=mk(cfg, mem, tp=TP),
        sparse_params=s))(params, toks[:, 0], caches, sp)
    l_topk = step(base.replace(selection="topk"))[0]
    l_thr = step(base.replace(selection="threshold", threshold=1.0))[0]
    # tau=1.0 drops everything -> must differ from topk
    assert not np.allclose(np.asarray(l_topk, np.float32),
                           np.asarray(l_thr, np.float32))


def test_profiler_attribution():
    prof = StageProfiler()
    pipe = MemoryPipeline(
        "p", prepare=lambda M: M, relevancy=lambda I, x: I,
        retrieve=lambda M, S: S, apply=lambda Mp, x: Mp,
        fused={"relevancy": ("relevancy", "retrieve")})
    pipe.run(jnp.zeros(4), jnp.zeros(4), profiler=prof)
    prof.record_total("p", sum(prof.stage_seconds["p"].values()) * 2)
    bd = prof.breakdown("p")
    assert abs(sum(bd.values()) - 1.0) < 1e-6
    assert 0.0 < prof.memory_fraction("p") <= 0.5 + 1e-9


# ---------------------------------------------------------------------------
# placement policy properties (hypothesis)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 21))
def test_placement_monotone_windows(log_ctx):
    """dense below min_context, dense above fallback, sparse allowed between."""
    cfg = get_arch("qwen3-32b")
    ctx = 1 << log_ctx
    path = placement.choose_path(cfg, cfg.memory, ctx)
    if ctx < cfg.memory.min_context:
        assert path == "dense"
    if ctx > cfg.memory.fallback_context:
        assert path == "dense"


def test_placement_prefers_sparse_at_long_context():
    cfg = get_arch("qwen3-32b")
    assert placement.choose_path(cfg, cfg.memory, 1 << 19) == "sparse"


def test_stage_costs_match_paper_table2_decades():
    """Arithmetic intensities land in the paper's order-of-magnitude bands
    (Table 2) for sparse attention at long context: relevancy/retrieval are
    memory-bound (low AI), apply/rest sit higher."""
    cfg = get_arch("qwen3-32b")
    costs = placement.sparse_attention_stage_costs(cfg, cfg.memory, 1 << 20)
    assert costs["retrieve"].intensity < 10
    assert costs["relevancy"].intensity < 100
    assert costs["apply"].intensity > costs["retrieve"].intensity
    assert costs["rest"].intensity > costs["retrieve"].intensity
    # relevancy+retrieval dominate the pipeline time at 1M context (Fig. 3)
    mem_s = {k: v.seconds() for k, v in costs.items()}
    assert mem_s["relevancy"] + mem_s["retrieve"] > mem_s["prepare"]
