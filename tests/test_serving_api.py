"""Request-level serving API (serving/api): submit/poll/drain semantics
and the ``generate()`` thin-wrapper guarantee.

``Engine.generate`` is now a wrapper over ``submit + drain`` whenever the
prompt batch fits the paged pool — it must stay BIT-IDENTICAL to the
dense-cache loop it replaced (``_generate_batched``), leave no residue in
the engine, and fall back to the dense loop whenever the pool cannot take
the batch (ssm families, paged=False, oversized, pool busy)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import init_params
from repro.serving import Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    return cfg, params


@pytest.mark.parametrize("method", ["none", "dsa"])
def test_generate_wrapper_bitmatches_dense_loop(setup, method):
    cfg, params = setup
    sc = ServeConfig(max_len=64, n_slots=3, method=method, tp=4, page=8,
                     kv_page_size=16)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(3, 16)),
                          jnp.int32)
    want = eng._generate_batched(prompts, 5)       # the old dense loop
    got = eng.generate(prompts, 5)                 # routes through the pool
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # no residue: the pool is drained, no handles or done entries linger
    assert not eng.busy() and not eng.done and not eng._handles
    assert eng.pool.pages_in_use() == 0


def test_generate_falls_back_when_pool_busy(setup):
    """A generate() call while requests are resident must not disturb the
    pool — it takes the dense-cache path and the resident stream finishes
    unchanged."""
    cfg, params = setup
    sc = ServeConfig(max_len=64, n_slots=2, method="none", tp=4,
                     kv_page_size=16)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    ref = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    resident = rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
    want_resident = ref.generate(jnp.asarray(resident)[None], 6)[0]
    other = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 8)),
                        jnp.int32)
    want_other = ref.generate(other, 4)

    h = eng.submit(Request(0, resident, 6))
    eng.poll()                                     # resident mid-decode
    got_other = eng.generate(other, 4)             # dense fallback
    np.testing.assert_array_equal(np.asarray(got_other),
                                  np.asarray(want_other))
    eng.drain()
    assert h.done
    np.testing.assert_array_equal(np.asarray(h.tokens, np.int32),
                                  want_resident)


def test_submit_rejects_duplicates_and_wrong_types(setup):
    cfg, params = setup
    sc = ServeConfig(max_len=64, n_slots=2, method="none", tp=4,
                     kv_page_size=16)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    with pytest.raises(TypeError):
        eng.submit((0, p, 3))                      # legacy tuple shape
    eng.submit(Request(0, p, 3))
    with pytest.raises(ValueError):
        eng.submit(Request(0, p, 3))               # rid already in flight
    eng.drain()
    eng.submit(Request(0, p, 3))                   # done rids are reusable
    done = eng.drain()
    assert sorted(done) == [0]


def test_handle_timing_and_result(setup):
    cfg, params = setup
    sc = ServeConfig(max_len=64, n_slots=2, method="none", tp=4,
                     kv_page_size=16)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    h = eng.submit(Request(0, rng.integers(0, cfg.vocab_size, size=8), 4))
    assert not h.done and h.ttft_s() is None
    eng.drain()
    assert h.done and len(h.tokens) == 4
    assert h.admitted is not None and h.first_token_t is not None
    assert h.finished >= h.first_token_t >= h.submitted
    assert h.ttft_s() >= 0 and h.per_token_s() >= 0
    d = h.as_dict()
    assert d["rid"] == 0 and d["n_tokens"] == 4
    assert h.text == " ".join(str(t) for t in h.tokens)
    np.testing.assert_array_equal(h.result(),
                                  np.asarray(h.tokens, np.int32))
