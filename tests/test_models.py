"""Per-architecture smoke tests: reduced same-family config, one forward /
train / prefill / decode step on CPU, asserting shapes + no NaNs. Plus
prefill-vs-decode state-consistency for the recurrent families."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import (init_params, train_loss, prefill, decode_step,
                          make_cache)

TP = 4


def _batch(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.rope_style == "mrope":
        b["positions3"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
        b["img_embeds"] = jax.random.normal(key, (B, 4, cfg.d_model),
                                            jnp.bfloat16)
    return b


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke(name):
    cfg = get_arch(name).smoke()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, tp=TP)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)

    loss = jax.jit(lambda p, b: train_loss(p, cfg, b, remat=True, tp=TP))(
        params, batch)
    assert np.isfinite(float(loss)), name
    assert 2.0 < float(loss) < 15.0, (name, float(loss))

    logits, caches = jax.jit(
        lambda p, t: prefill(p, cfg, t, max_len=S + 8, tp=TP,
                             positions3=batch.get("positions3")))(
        params, batch["tokens"])
    assert logits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), name

    logits2, caches = jax.jit(
        lambda p, t, c: decode_step(p, cfg, t, c, tp=TP))(
        params, batch["tokens"][:, 0], caches)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits2).any()), name
    assert int(caches["length"]) == S + 1


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_consistency(name):
    """prefill(S) last logits == prefill(S/2) + S/2 single decode steps —
    for EVERY architecture family (KV caches, Mamba2 state, xLSTM state,
    shared-attention hybrid, M-RoPE positions)."""
    cfg = get_arch(name).smoke()
    if cfg.n_experts:
        # capacity-based MoE drops different tokens under prefill vs decode
        # grouping (a known GShard dispatch artifact); with ample capacity
        # the two MUST agree exactly.
        cfg = cfg.replace(capacity_factor=4.0)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key, tp=TP)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    p3 = (jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
          if cfg.rope_style == "mrope" else None)
    ref_logits, _ = jax.jit(lambda p, t: prefill(
        p, cfg, t, max_len=S, tp=TP, positions3=p3))(params, toks)
    half = S // 2
    p3h = p3[:, :, :half] if p3 is not None else None
    logits, caches = jax.jit(lambda p, t: prefill(
        p, cfg, t, max_len=S, tp=TP, positions3=p3h))(params, toks[:, :half])
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c, tp=TP))
    for i in range(half, S):
        logits, caches = step(params, toks[:, i], caches)
    err = np.abs(np.asarray(ref_logits, np.float32) -
                 np.asarray(logits, np.float32)).max()
    assert err < 0.25, (name, err)  # bf16 accumulation noise


def test_padded_vocab_masked():
    cfg = get_arch("granite-moe-1b-a400m").smoke().replace(vocab_size=500)
    assert cfg.padded_vocab == 512
    params = init_params(cfg, jax.random.PRNGKey(0), tp=TP)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 500)
    logits, _ = jax.jit(lambda p, t: prefill(p, cfg, t, tp=TP))(params, toks)
    pad_max = float(jnp.max(logits[:, 500:]))
    real_max = float(jnp.max(logits[:, :500]))
    assert pad_max < real_max - 100  # -inf-masked pad rows never win


def test_dead_head_padding_stays_zero():
    """qwen2-7b pads 28->32 heads under TP16; dead-head grads must be zero."""
    cfg = get_arch("qwen2-7b").smoke().replace(n_heads=6, n_kv_heads=2,
                                               head_dim=16, d_model=96,
                                               d_ff=128)
    tp = 4  # 6 heads -> padded to 8
    assert cfg.padded_heads(tp) == 8
    params = init_params(cfg, jax.random.PRNGKey(0), tp=tp)
    batch = _batch(cfg, jax.random.PRNGKey(1), 2, 16)
    grads = jax.grad(lambda p: train_loss(p, cfg, batch, tp=tp))(params)
    gwq = np.asarray(grads["layers"]["attn"]["wq"], np.float32)
    L, d, _ = gwq.shape
    gwq = gwq.reshape(L, d, 8, 16)
    assert np.abs(gwq[:, :, 6:, :]).max() == 0.0  # dead-head slices silent
    gwo = np.asarray(grads["layers"]["attn"]["wo"], np.float32)
    gwo = gwo.reshape(L, 8, 16, d)
    assert np.abs(gwo[:, 6:]).max() == 0.0
