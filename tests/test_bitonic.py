"""Property tests for the in-kernel bitonic sort primitive (hypothesis)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.bitonic import bitonic_sort_desc, bitonic_topk


@st.composite
def keys_arrays(draw):
    log_n = draw(st.integers(1, 9))
    n = 1 << log_n
    rows = draw(st.integers(1, 3))
    # allow_subnormal=False: XLA on CPU flushes denormals to zero, which
    # would disagree with numpy's total order (not a sort property).
    vals = draw(st.lists(st.floats(-100, 100, width=32,
                                   allow_subnormal=False),
                         min_size=rows * n, max_size=rows * n))
    arr = np.asarray(vals, np.float32).reshape(rows, n)
    # quantize to force ties
    if draw(st.booleans()):
        arr = np.round(arr)
    return arr


@settings(max_examples=40, deadline=None)
@given(keys_arrays())
def test_sort_matches_numpy(keys):
    rows, n = keys.shape
    idx = np.broadcast_to(np.arange(n, dtype=np.int32), keys.shape)
    ks, vs = bitonic_sort_desc(jnp.asarray(keys), jnp.asarray(idx))
    ks, vs = np.asarray(ks), np.asarray(vs)
    ref = -np.sort(-keys, axis=-1)
    assert np.array_equal(ks, ref)
    # payload is a permutation and consistent with keys
    assert np.array_equal(np.sort(vs, axis=-1),
                          np.broadcast_to(np.arange(n), keys.shape))
    assert np.array_equal(np.take_along_axis(keys, vs, -1), ref)


@settings(max_examples=25, deadline=None)
@given(keys_arrays(), st.integers(1, 16))
def test_topk_subset_of_sort(keys, k):
    n = keys.shape[-1]
    k = min(k, n)
    idx = np.broadcast_to(np.arange(n, dtype=np.int32), keys.shape)
    kv, ki = bitonic_topk(jnp.asarray(keys), jnp.asarray(idx), k)
    ref_v = -np.sort(-keys, axis=-1)[..., :k]
    assert np.array_equal(np.asarray(kv), ref_v)


def test_non_power_of_two_rejected():
    with pytest.raises(AssertionError):
        bitonic_sort_desc(jnp.zeros((3,)), jnp.zeros((3,), jnp.int32))
