"""Fused multi-step decode (``ServeConfig(fused_steps=K)``, serving/fused).

Load-bearing properties:

  * ORACLE BIT-MATCH: for every memory method (none/dsa/seer/lserve) and
    every offload pipeline (inline, sync, overlap — incl. validate mode,
    2 selection shards, and the 2-device apply mesh), ``fused(K)`` emits
    token-for-token what K separate ``step_pool()`` calls emit, while
    consuming several device steps per host dispatch;
  * EARLY EXIT: a window hands control back to the host at the exact step
    a slot finishes (admission timing unchanged) or a FLARE trigger fires
    (retrieval launch timing unchanged), in every retrieval mode;
  * the new ``StepEvents`` result iterates like the legacy tuple list, and
    the nested ``OffloadConfig`` surface validates at construction time
    and round-trips through ``dataclasses.replace`` on either surface;
  * the page-table view cache re-slices only when the bucket or the pool's
    host table actually changed;
  * hypothesis property: arbitrary window widths x slot-length mixes stay
    bit-exact against the stepped loop.

CI runs this file under 1, 2 and 4 host devices (the hetero matrix legs);
meshes clamp to the available device count, so every property holds at any
topology.
"""
import dataclasses
import functools
import warnings

import numpy as np
import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch
from repro.data import build_corpus
from repro.retrieval import RetrievalConfig
from repro.serving import Engine, OffloadConfig, Request, ServeConfig, \
    StepEvents


@functools.lru_cache(maxsize=1)
def _setup_cached():
    from repro.models import init_params

    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    corpus = build_corpus(48, retrieval_vocab=128, doc_max=8,
                          gen_vocab=cfg.vocab_size, embed_dim=16, seed=0)
    return cfg, params, corpus


@pytest.fixture(scope="module")
def setup():
    return _setup_cached()


BASE = dict(max_len=128, n_slots=2, tp=4, page=8, kv_page_size=16)


def _run(cfg, params, sc, prompts, max_new, max_dispatches=200):
    """Drive the engine to drain; returns (streams, fired, window_steps)."""
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    for i, (p, mn) in enumerate(zip(prompts, max_new)):
        eng.submit(Request(i, p, mn))
    streams, fired, windows = {}, [], []
    for _ in range(max_dispatches):
        ev = eng.poll()
        for rid, _slot, tok in ev:
            streams.setdefault(rid, []).append(tok)
        fired.extend(ev.fired)
        if ev.steps:
            windows.append(ev.steps)
        if all(s.done for s in eng.slots.slots) and \
                not eng.has_prefill_work() and not eng.has_retrieval_work():
            break
    return streams, fired, windows, eng


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in sizes]


# ---------------------------------------------------------------------------
# oracle matrix: fused(K) == K x step_pool() for every method x pipeline
# ---------------------------------------------------------------------------


MATRIX = [
    ("none", dict()),
    ("dsa", dict()),
    ("seer", dict()),
    ("lserve", dict()),
    ("dsa", dict(offload_cfg=OffloadConfig(mode="sync", validate=True))),
    ("dsa", dict(offload_cfg=OffloadConfig(mode="overlap"))),
    ("seer", dict(offload_cfg=OffloadConfig(mode="overlap",
                                            validate=True))),
    ("lserve", dict(offload_cfg=OffloadConfig(mode="sync"))),
]


@pytest.mark.parametrize("method,extra", MATRIX)
def test_fused_matches_stepped(setup, method, extra):
    cfg, params, _ = setup
    prompts = _prompts(cfg, (16, 9))
    max_new = (6, 9)
    ref, _, _, _ = _run(cfg, params,
                        ServeConfig(method=method, **extra, **BASE),
                        prompts, max_new)
    got, _, windows, eng = _run(
        cfg, params, ServeConfig(method=method, fused_steps=4,
                                 **extra, **BASE),
        prompts, max_new)
    assert got == ref
    # the windows actually amortized host dispatches
    assert eng.stats["host_steps"] < eng.stats["decode_steps"]
    assert max(windows) > 1
    assert eng.pool.pages_in_use() == 0


def test_fused_composes_shards_and_mesh(setup):
    """fused windows x 2 selection shards x 2-device apply mesh x validate:
    the full PR-4/PR-5 topology behind one dispatch per window."""
    cfg, params, _ = setup
    prompts = _prompts(cfg, (16, 24), seed=5)
    max_new = (6, 6)
    oc = OffloadConfig(mode="overlap", validate=True, shards=2, main_mesh=2)
    ref, _, _, _ = _run(
        cfg, params, ServeConfig(method="dsa", offload_cfg=oc, **BASE),
        prompts, max_new)
    got, _, _, eng = _run(
        cfg, params,
        ServeConfig(method="dsa", fused_steps=4, offload_cfg=oc, **BASE),
        prompts, max_new)
    assert got == ref
    f = eng.hetero.profiler.summary()["fused"]
    assert f["windows"] >= 1 and f["steps_per_dispatch"] > 1


# ---------------------------------------------------------------------------
# early exit
# ---------------------------------------------------------------------------


def test_early_exit_on_finish(setup):
    """Staggered max_new: the first window must stop AT the finishing step
    (3), not run the full K=4 — admission/release timing stays identical
    to the stepped loop."""
    cfg, params, _ = setup
    prompts = _prompts(cfg, (16, 9), seed=2)
    ref, _, _, _ = _run(cfg, params, ServeConfig(method="dsa", **BASE),
                        prompts, (3, 7))
    got, _, windows, _ = _run(
        cfg, params, ServeConfig(method="dsa", fused_steps=4, **BASE),
        prompts, (3, 7))
    assert got == ref
    assert windows[0] == 3          # early exit at slot 0's last token
    assert sum(windows) == 7        # no wasted device steps


@pytest.mark.parametrize("rmode", ["inline", "sync", "overlap"])
def test_early_exit_on_trigger(setup, rmode):
    """tau=1.1 FLARE fires as soon as the cooldown opens; the window must
    exit at the trigger step so the retrieval launches on the same step it
    would have under the stepped loop — same fired slots, same doc ids,
    same spliced streams."""
    cfg, params, corpus = setup
    rcfg = RetrievalConfig(kind="rag", mode=rmode, corpus=corpus, k=2,
                           trigger="flare", tau=1.1, min_interval=3,
                           max_retrievals=1, query_window=6)
    prompts = _prompts(cfg, (16, 9), seed=3)
    ref, rfired, _, reng = _run(
        cfg, params, ServeConfig(method="dsa", retrieval=rcfg, **BASE),
        prompts, (10, 10))
    got, gfired, _, geng = _run(
        cfg, params,
        ServeConfig(method="dsa", retrieval=rcfg, fused_steps=4, **BASE),
        prompts, (10, 10))
    assert got == ref
    assert gfired == rfired and gfired
    assert [e["ids"] for e in geng.retrieval.events] == \
           [e["ids"] for e in reng.retrieval.events]


def test_trigger_composed_with_offload(setup):
    """Retrieval triggers + hetero offload inside fused windows: the
    armed/arm_after countdown gates must reproduce the host gate decisions
    exactly when both services share the pool."""
    cfg, params, corpus = setup
    rcfg = RetrievalConfig(kind="rag", mode="overlap", corpus=corpus, k=2,
                           trigger="flare", tau=1.1, min_interval=3,
                           max_retrievals=1, query_window=6)
    prompts = _prompts(cfg, (16, 9), seed=4)
    ref, rf, _, _ = _run(
        cfg, params,
        ServeConfig(method="dsa", retrieval=rcfg,
                    offload_cfg=OffloadConfig(mode="overlap"), **BASE),
        prompts, (10, 10))
    got, gf, _, _ = _run(
        cfg, params,
        ServeConfig(method="dsa", retrieval=rcfg,
                    offload_cfg=OffloadConfig(mode="overlap"),
                    fused_steps=4, **BASE),
        prompts, (10, 10))
    assert got == ref and gf == rf and gf


# ---------------------------------------------------------------------------
# API surface: StepEvents shim, OffloadConfig validation, view cache
# ---------------------------------------------------------------------------


def test_step_events_legacy_shim():
    ev = StepEvents(emissions=[(7, 0, 11), (8, 1, 12)], finished=[1],
                    fired=[0], steps=2)
    assert list(ev) == [(7, 0, 11), (8, 1, 12)]
    assert len(ev) == 2 and bool(ev) and ev[0] == (7, 0, 11)
    assert not StepEvents() and len(StepEvents()) == 0


def test_offload_config_validation():
    with pytest.raises(ValueError):
        OffloadConfig(mode="bogus")
    with pytest.raises(ValueError):
        OffloadConfig(shards=0)
    with pytest.raises(ValueError):
        OffloadConfig(mode="off", shards=2)
    with pytest.raises(ValueError):
        OffloadConfig(mode="off", main_mesh=2)
    with pytest.raises(ValueError), pytest.warns(DeprecationWarning):
        ServeConfig(offload="nope")
    with pytest.raises(ValueError):
        ServeConfig(fused_steps=0)
    with pytest.raises(ValueError):
        ServeConfig(fused_steps=4, paged=False)


def test_offload_config_precedence_and_replace():
    # nested populates the flat mirror, silently — the supported surface
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sc = ServeConfig(offload_cfg=OffloadConfig(mode="overlap",
                                                   shards=2))
    assert (sc.offload, sc.offload_shards) == ("overlap", 2)
    # flat kwargs are DEPRECATED: they warn, and still win over a
    # conflicting nested config (pre-existing call sites unchanged)
    with pytest.warns(DeprecationWarning, match="offload_cfg"):
        sc = ServeConfig(offload="sync",
                         offload_cfg=OffloadConfig(mode="overlap"))
    assert sc.offload == "sync" and sc.offload_cfg.mode == "sync"
    with pytest.warns(DeprecationWarning, match="deprecated"):
        sc = ServeConfig(offload="overlap", offload_shards=2)
    assert sc.offload_cfg == OffloadConfig(mode="overlap", shards=2)
    # replace on the FLAT surface re-derives the nested view (and warns)
    with pytest.warns(DeprecationWarning):
        sc = dataclasses.replace(ServeConfig(), offload="overlap")
    assert sc.offload_cfg.mode == "overlap"
    # replace on the NESTED surface updates the flat mirror silently, and
    # an unrelated replace() carries the coherent pair without warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sc = dataclasses.replace(ServeConfig(),
                                 offload_cfg=OffloadConfig(mode="sync"))
        assert sc.offload == "sync"
        sc2 = dataclasses.replace(sc, fused_steps=2)
    assert sc2.offload_cfg.mode == "sync" and sc2.offload == "sync"
    assert sc2.fused_steps == 2


def test_table_view_cache(setup):
    """Steady-state decode reuses the sliced table view; admissions and
    releases (host-table pushes) invalidate it."""
    cfg, params = setup[0], setup[1]
    sc = ServeConfig(method="none", **BASE)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (16, 9), seed=6)
    eng.submit(Request(0, prompts[0], 4))
    eng.submit(Request(1, prompts[1], 4))
    eng.poll()                             # admit both (one decode step)
    lengths = np.where(eng._decode_live(), eng.slots.lengths(),
                       0).astype(np.int32)
    v1 = eng._table_view(lengths)
    v2 = eng._table_view(lengths)
    assert v1 is v2                        # cache hit: same buffer object
    ver = eng.pool.table_version
    eng.step_pool()                        # decode does not edit the table
    assert eng.pool.table_version == ver
    for _ in range(8):                     # drain to release (table push)
        eng.step_pool()
    assert eng.pool.table_version > ver
    v3 = eng._table_view(lengths)
    assert v3 is not v1                    # version bump invalidated it


# ---------------------------------------------------------------------------
# property: arbitrary window widths x slot-length mixes
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=6)
@given(st.integers(2, 6), st.integers(4, 20), st.integers(4, 20),
       st.integers(1, 7), st.integers(1, 7))
def test_fused_property_bitmatch(K, n1, n2, m1, m2):
    cfg, params, _ = _setup_cached()
    prompts = _prompts(cfg, (n1, n2), seed=n1 * 29 + n2)
    ref, _, _, _ = _run(cfg, params, ServeConfig(method="dsa", **BASE),
                        prompts, (m1, m2))
    got, _, _, _ = _run(
        cfg, params, ServeConfig(method="dsa", fused_steps=K, **BASE),
        prompts, (m1, m2))
    assert got == ref
