"""Training substrate: optimizer properties, checkpoint roundtrip +
resharding restore, gradient compression with error feedback, data pipeline
determinism, straggler/elastic planning."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.data import TokenStream, pack_documents
from repro.distributed import checkpoint as ckpt
from repro.distributed.collectives import (compress_int8, decompress_int8,
                                           compressed_grads_with_feedback)
from repro.distributed.elastic import StragglerMonitor, plan_mesh
from repro.models import init_params
from repro.train import OptConfig, Trainer, TrainConfig, adamw_update, \
    init_opt_state


def test_adamw_minimizes_quadratic():
    oc = OptConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(grads, state, params, oc)
    assert float(jnp.abs(params["w"]).max()) < 0.3


@settings(max_examples=30, deadline=None)
@given(st.floats(0.1, 100.0))
def test_grad_clip_bounds_update(scale):
    oc = OptConfig(lr=1e-2, warmup_steps=0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = init_opt_state(params)
    grads = {"w": jnp.full((4,), scale)}
    new, _, stats = adamw_update(grads, state, params, oc)
    assert float(stats["grad_norm"]) == pytest.approx(scale * 2.0, rel=1e-4)
    assert float(jnp.abs(new["w"]).max()) <= oc.lr * 1.1


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, width=32), min_size=4, max_size=64))
def test_int8_compression_bounded_error(vals):
    g = jnp.asarray(vals, jnp.float32)
    q, s = compress_int8(g)
    back = decompress_int8(q, s)
    assert float(jnp.abs(back - g).max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.asarray([1e-4, 1.0])}
    sent, resid = compressed_grads_with_feedback(g, None, "int8")
    # small component lost this round, kept in residual
    assert float(jnp.abs(resid["w"][0])) > 0
    # after enough rounds the residual feeds back into what is sent
    total_sent = jnp.zeros(2)
    r = None
    for _ in range(300):
        sent, r = compressed_grads_with_feedback(g, r, "int8")
        total_sent = total_sent + sent["w"]
    np.testing.assert_allclose(np.asarray(total_sent / 300),
                               np.asarray(g["w"]), rtol=0.05, atol=1e-4)


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, {"params": params})
    assert ckpt.latest_step(d) == 7
    like = jax.tree.map(jnp.zeros_like, {"params": params})
    back = ckpt.restore(d, 7, like)
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(back["params"])
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_atomicity(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(5):
        ckpt.save(d, s, {"x": jnp.ones(3) * s}, keep=2)
    steps = sorted(p for p in os.listdir(d) if p.startswith("step_"))
    assert len(steps) == 2 and ckpt.latest_step(d) == 4
    assert not [p for p in os.listdir(d) if p.startswith(".tmp")]


def test_trainer_restores_after_crash(tmp_path):
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=1, total_steps=50),
                     tp=4, ckpt_dir=str(tmp_path / "ck"), ckpt_every=2)
    tr = Trainer(cfg, tc, params)
    ds = TokenStream(cfg.vocab_size, 32, 2, seed=0)
    it = iter(ds)
    for _ in range(4):
        tr.train_step({k: jnp.asarray(v) for k, v in next(it).items()})
    step_before = tr.step
    loss_ref = tr.train_step(
        {k: jnp.asarray(v) for k, v in next(it).items()})["loss"]
    # "crash": new Trainer from fresh params restores the checkpoint
    tr2 = Trainer(cfg, tc, init_params(cfg, jax.random.PRNGKey(9), tp=4))
    assert tr2.step == step_before
    ds2 = TokenStream(cfg.vocab_size, 32, 2, seed=0)
    it2 = iter(ds2)
    for _ in range(4):
        next(it2)
    loss_resumed = tr2.train_step(
        {k: jnp.asarray(v) for k, v in next(it2).items()})["loss"]
    assert loss_resumed == pytest.approx(loss_ref, rel=1e-3)


def test_data_determinism_and_host_sharding():
    a = TokenStream(512, 64, 4, seed=1, host_index=0, num_hosts=2).next_batch()
    b = TokenStream(512, 64, 4, seed=1, host_index=0, num_hosts=2).next_batch()
    c = TokenStream(512, 64, 4, seed=1, host_index=1, num_hosts=2).next_batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < 512


def test_pack_documents():
    docs = [[1] * 5, [2] * 9, [3] * 3]
    rows = pack_documents(docs, seq_len=8, pad_id=0)
    assert rows.shape[1] == 8
    assert rows.sum() == 5 + 18 + 9  # nothing lost


def test_straggler_and_elastic_plan():
    mon = StragglerMonitor(factor=2.0)
    for i in range(8):
        for _ in range(4):
            mon.record(f"host{i}", 1.0 if i else 5.0)  # host0 is slow
    assert mon.stragglers() == ["host0"]
    shape, axes = plan_mesh(512, model_parallel=16, multi_pod=True)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    shape, axes = plan_mesh(480, model_parallel=16)  # 2 hosts lost
    assert shape == (30, 16)
    with pytest.raises(ValueError):
        plan_mesh(8, model_parallel=16)
