"""Sharded hetero offload (src/repro/hetero/sharded.py).

Load-bearing properties:

  * pooled decode with ``offload_shards=2`` (sync AND overlap) emits token
    streams BIT-IDENTICAL to ``offload_shards=1`` and to the fully
    synchronous configuration with inline retrieval, for dsa / seer /
    lserve, on a mixed pool containing a retrieval-enabled slot — the
    per-shard candidate merge is exact (index-only exchange loses nothing);
  * each shard's TransferLedger reports at most 8 bytes per candidate per
    step on the up link (k (val, idx) pairs — never scores, never KV), and
    per-shard per-step traffic stays below one KV page;
  * the sharded top-k merge equals the exact reference top-k for random
    shard counts and ragged/empty/all-masked shards (hypothesis property,
    runs under the conftest fallback shim when hypothesis is absent);
  * per-slot lookahead invalidation: membership events (staggered
    admission, retrieval splice) PATCH the affected rows instead of
    discarding the pending lookahead — cold starts stay at 1 per fallback
    window entry (the reuse-count regression for PR 4's satellite fix);
  * ``distributed_paged_sparse_decode`` (LSE-merged sequence-parallel
    apply over the paged-pool view) matches the single-device paged
    attention, including through ``decode_step_paged_presel``'s
    ``page_attn`` seam.

CI runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count``
of 2 AND 4 (the ``test-sharded`` matrix) so every topology — shards
sharing one offload device, one device per shard — is exercised; with one
device all transfers degenerate to no-ops and the properties still hold.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch
from repro.data import build_corpus
from repro.distributed.topk import distributed_paged_sparse_decode
from repro.hetero.select import make_offload_select, merge_shard_topk
from repro.kernels import ops, ref
from repro.launch.mesh import make_mesh
from repro.models import init_params, model as M
from repro.retrieval import RetrievalConfig
from repro.serving import Engine, OffloadConfig, Request, ServeConfig, \
    Scheduler

NEG_INF = -1e30


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    corpus = build_corpus(48, retrieval_vocab=128, doc_max=8,
                          gen_vocab=cfg.vocab_size, embed_dim=16, seed=0)
    return cfg, params, corpus


def _drain(eng, n_steps):
    got = {}
    for _ in range(n_steps):
        for rid, _slot, tok in eng.poll():
            got.setdefault(rid, []).append(tok)
    return got


def _free_pages_zero(pool) -> bool:
    idx = np.asarray([0] + pool.free, np.int32)
    k = np.asarray(pool.device["k_pages"][:, idx], np.float32)
    v = np.asarray(pool.device["v_pages"][:, idx], np.float32)
    return not k.any() and not v.any()


def _rcfg(corpus, mode):
    return RetrievalConfig(mode=mode, kind="rag", corpus=corpus, k=2,
                           trigger="flare", tau=1.1, min_interval=3,
                           max_retrievals=1, query_window=6)


# ---------------------------------------------------------------------------
# serving bit-exactness: shards=2 == shards=1 == inline-retrieval pairing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["dsa", "seer", "lserve"])
def test_sharded_bitmatches_single_and_inline(setup, method):
    """Mixed pool (one retrieval-enabled slot + one sparse slot): the
    sharded topologies serve the same tokens as the single-offload-device
    executor and the fully synchronous inline-retrieval schedule, and the
    per-shard up link carries at most 8 bytes per candidate per step."""
    cfg, params, corpus = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (16, 24)]
    streams, events = {}, {}
    sharded_eng = None
    for off, rmode, shards in (("sync", "inline", 1),
                               ("sync", "sync", 2),
                               ("overlap", "overlap", 2)):
        sc = ServeConfig(max_len=128, n_slots=2, method=method, tp=4,
                         page=8, kv_page_size=16,
                         offload_cfg=OffloadConfig(
                             mode=off, shards=shards,
                             validate=(off == "overlap")),
                         retrieval=_rcfg(corpus, rmode))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, 6, retrieval=(i == 0)))
        key = (off, rmode, shards)
        streams[key] = _drain(eng, 24)
        events[key] = [(e["slot"], tuple(e["ids"])) for e in
                       eng.retrieval.events]
        assert events[key], "no retrieval fired"
        assert eng.pool.pages_in_use() == 0
        assert _free_pages_zero(eng.pool)      # zero-page invariant
        if shards == 2:
            sharded_eng = eng
    first = streams[("sync", "inline", 1)]
    assert all(s == first for s in streams.values())
    assert len(set(map(tuple, events.values()))) == 1

    # index-only invariant: per shard, the up link moved exactly k
    # (val, idx) pairs per offloaded step — 8 bytes per candidate, less
    # than one KV page (what a page-shipping design would move)
    hx = sharded_eng.hetero
    L, B = cfg.n_layers, sc.n_slots
    kv_page = sc.kv_page_size * cfg.n_kv_heads * cfg.hd * 2 * 2  # bf16, K+V
    for led, shard in zip(hx.ledgers, hx.shards):
        assert led.up_bytes <= led.steps * 8 * L * B * shard.n_part
        assert led.up_bytes / led.steps < kv_page
    rep = hx.report()
    assert rep["shards"]["n_shards"] == 2
    assert len(rep["shards"]["per_shard_transfer"]) == 2


def test_sharded_under_scheduler(setup):
    """Chunked admission + staggered completion through the Scheduler:
    overlapped 2-shard serving bit-matches the synchronous single-shard
    executor end to end."""
    cfg, params, _ = setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (10, 40, 16, 33)]
    streams = {}
    for off, shards in (("sync", 1), ("overlap", 2)):
        sc = ServeConfig(max_len=128, n_slots=2, method="dsa", tp=4, page=8,
                         kv_page_size=16, prefill_chunk=16,
                         chunk_threshold=32,
                         offload_cfg=OffloadConfig(mode=off,
                                                   shards=shards))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
        sch = Scheduler(eng, prefill_token_budget=32)
        rids = [sch.submit(p, max_new=4) for p in prompts]
        done = sch.run()
        assert sorted(done) == sorted(rids)
        streams[(off, shards)] = {r: done[r].tokens for r in done}
        assert eng.pool.pages_in_use() == 0
        assert _free_pages_zero(eng.pool)
    assert streams[("sync", 1)] == streams[("overlap", 2)]


def test_shard_ownership_alignment(setup):
    """The paged pool's page->shard map agrees with the executor's static
    ingest windows, and ServeConfig aligns max_len so every shard covers a
    whole number of selection and KV pages."""
    cfg, params, _ = setup
    sc = ServeConfig(max_len=100, n_slots=2, method="dsa", tp=4, page=8,
                     kv_page_size=16,
                     offload_cfg=OffloadConfig(mode="sync", shards=2))
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    assert eng.sc.max_len % (2 * 16) == 0 and eng.sc.max_len >= 100
    eng._ensure_pool()
    owners = eng.pool.shard_owners(2)
    local = eng.sc.max_len // 2
    for s, shard in enumerate(eng.hetero.shards):
        assert shard.tok_lo == s * local and shard.n_tok == local
        pages = np.flatnonzero(owners == s) * sc.kv_page_size
        assert pages.min() == shard.tok_lo
        assert pages.max() + sc.kv_page_size == shard.tok_lo + shard.n_tok
        view = eng.pool.shard_table_view(2, s)
        assert view.shape == (sc.n_slots, local // sc.kv_page_size)


# ---------------------------------------------------------------------------
# per-slot lookahead invalidation (reuse-count regression)
# ---------------------------------------------------------------------------


def test_lookahead_survives_membership_events(setup):
    """Staggered admission and a retrieval splice no longer discard the
    pending lookahead: the executor patches only the affected slots' rows,
    so the whole run pays exactly ONE cold start (pool entry) and every
    other step reuses the overlapped selection."""
    cfg, params, corpus = setup
    rng = np.random.default_rng(3)
    sc = ServeConfig(max_len=128, n_slots=2, method="dsa", tp=4, page=8,
                     kv_page_size=16,
                     offload_cfg=OffloadConfig(mode="overlap",
                                               validate=True),
                     retrieval=_rcfg(corpus, "overlap"))
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, size=16), 8,
                       retrieval=True))
    got = {}
    for step in range(26):
        for rid, _s, tok in eng.poll():
            got.setdefault(rid, []).append(tok)
        if step == 2:    # staggered admission: membership change mid-decode
            eng.submit(Request(
                1, rng.integers(0, cfg.vocab_size, size=12), 6,
                retrieval=False))
    assert len(got[0]) == 8 and len(got[1]) == 6
    assert eng.retrieval.events, "no splice landed — regression unexercised"
    p = eng.hetero.profiler
    assert p.lookahead_cold == 1, \
        f"membership events cold-started the lookahead: {p.lookahead_cold}"
    # at least the admission and the splice completion were row-patches
    assert p.lookahead_patched >= 2
    assert p.lookahead_hits + p.lookahead_cold == p.offload_steps
    assert p.lookahead_hits > p.lookahead_patched


# ---------------------------------------------------------------------------
# sharded top-k merge == exact reference top-k (property)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 5), st.integers(1, 24),
       st.booleans())
def test_sharded_topk_merge_matches_ref(seed, n_shards, k, masked):
    """Per-shard exact top-k over ragged contiguous score slices +
    candidate merge == ``ref.relevancy_topk`` over the whole key axis, bit
    for bit — values, indices, AND tie order (ReLU scores tie at exact 0.0
    often). Empty shards and shards entirely past the live length
    (all-masked) contribute nothing / NEG_INF candidates and must not
    perturb the merge. Scores are computed once and sliced — the property
    of the MERGE is that it loses nothing whenever the per-shard scores
    equal the global ones, which is what the executor's per-page summary
    einsums provide (each page's score depends only on its own summary
    row)."""
    rng = np.random.default_rng(seed)
    B, Hq, dk = int(rng.integers(1, 4)), 2, 8
    S = int(rng.integers(n_shards, 40))
    q = jnp.asarray(rng.normal(size=(B, Hq, dk)), jnp.float32)
    keys = jnp.asarray(rng.normal(size=(B, S, dk)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.0, 1.0, size=(B, Hq)), jnp.float32)
    length = int(rng.integers(0, S + 1)) if masked else S

    scores = np.asarray(ref.relevancy_scores(q, keys, w))
    scores = np.where(np.arange(S)[None, :] < length, scores, NEG_INF)

    # oracle: global masked scores -> exact top-k (== ref.relevancy_topk
    # composed with the live mask)
    ref_v, ref_i = jax.lax.top_k(jnp.asarray(scores), min(k, S))
    if not masked:
        rv2, ri2 = ref.relevancy_topk(q, keys, w, k)
        np.testing.assert_array_equal(np.asarray(ref_v), np.asarray(rv2))
        np.testing.assert_array_equal(np.asarray(ref_i), np.asarray(ri2))

    # ragged contiguous shard cuts (possibly empty)
    bounds = [0] + sorted(rng.integers(0, S + 1,
                                       size=n_shards - 1).tolist()) + [S]
    vals, idx = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi == lo:
            continue                      # empty shard: nothing to send
        v, i = jax.lax.top_k(jnp.asarray(scores[:, lo:hi]),
                             min(k, hi - lo))
        vals.append(np.asarray(v))
        idx.append(np.asarray(i) + lo)    # global coordinates
    mv, mi = merge_shard_topk(jnp.asarray(np.concatenate(vals, -1)),
                              jnp.asarray(np.concatenate(idx, -1)),
                              min(k, S))
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(mv), np.asarray(ref_v))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_windowed_bundles_match_full_select(seed, n_shards):
    """End-to-end bundle property: ingesting one key stream through ragged
    window bundles and merging their partial selections reproduces the full
    bundle's selection exactly (windowed ingest routes every token to the
    owning shard and drops the rest)."""
    cfg = get_arch("llama3.2-1b").smoke()
    mem = cfg.memory
    rng = np.random.default_rng(seed)
    page, max_len, n_slots = 8, 64, 2
    from repro.core.methods import get_sparse_method
    sp = get_sparse_method("dsa")[0](jax.random.PRNGKey(seed % 97), cfg,
                                     mem, stacked=True)
    full = make_offload_select("dsa", cfg, mem, dsa_page=page,
                               n_slots=n_slots, max_len=max_len)
    # ragged page-aligned windows covering [0, max_len)
    P = max_len // page
    cuts = sorted(set([0, P] + rng.integers(0, P + 1,
                                            size=n_shards - 1).tolist()))
    windows = [(lo * page, (hi - lo) * page)
               for lo, hi in zip(cuts[:-1], cuts[1:]) if hi > lo]
    shards = [make_offload_select("dsa", cfg, mem, dsa_page=page,
                                  n_slots=n_slots, max_len=max_len,
                                  window=w) for w in windows]

    lens = rng.integers(1, max_len + 1, size=n_slots).astype(np.int32)
    S = int(lens.max())
    kv, hd = cfg.n_kv_heads, cfg.hd
    k_span = jnp.asarray(rng.normal(size=(cfg.n_layers, n_slots, S, kv, hd)),
                         jnp.float32)
    q = jnp.asarray(rng.normal(
        size=(cfg.n_layers, n_slots, cfg.padded_heads(4), hd)), jnp.float32)
    slot_ids = jnp.arange(n_slots, dtype=jnp.int32)
    start = jnp.zeros((n_slots,), jnp.int32)
    n_valid = jnp.asarray(lens)
    lengths = jnp.asarray(lens)

    s_full = full.ingest_span(full.summary_init(), sp, k_span, slot_ids,
                              start, n_valid)
    want = np.asarray(full.select(sp, s_full, q, lengths))

    vals, idx = [], []
    for sh in shards:
        s_sh = sh.ingest_span(sh.summary_init(), sp, k_span, slot_ids,
                              start, n_valid)
        v, i = sh.select_partial(sp, s_sh, q, lengths)
        vals.append(np.asarray(v))
        idx.append(np.asarray(i))
    got = np.asarray(full.finalize(jnp.asarray(np.concatenate(vals, -1)),
                                   jnp.asarray(np.concatenate(idx, -1)),
                                   lengths))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# LSE-merged sequence-parallel apply over the paged-pool view
# ---------------------------------------------------------------------------


def test_distributed_paged_sparse_decode_matches_single():
    """Sequence-parallel sparse decode over the gathered pool view (zero
    pages outside live regions, per-slot lengths, -1 holes from merged
    selections) matches single-device paged attention, directly and through
    the ``decode_step_paged_presel`` page_attn seam."""
    rng = np.random.default_rng(0)
    B, S, KV, dh, Hq, ps = 2, 128, 2, 16, 4, 8
    lengths = np.asarray([70, 33], np.int32)
    k = np.zeros((B, S, KV, dh), np.float32)
    v = np.zeros((B, S, KV, dh), np.float32)
    for b in range(B):   # zero-page invariant: dead region is exact zeros
        k[b, : lengths[b]] = rng.normal(size=(lengths[b], KV, dh))
        v[b, : lengths[b]] = rng.normal(size=(lengths[b], KV, dh))
    q = rng.normal(size=(B, Hq, dh)).astype(np.float32)
    pids = np.full((B, 6), -1, np.int32)     # -1 holes mid-selection
    pids[0, :4] = [0, 3, 8, 2]
    pids[1, :3] = [4, 1, 0]

    ref_out, ref_lse = ops.paged_decode_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(pids),
        jnp.asarray(lengths), page_size=ps)
    mesh = make_mesh((jax.device_count(),), ("model",))
    out, lse = distributed_paged_sparse_decode(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(pids),
        jnp.asarray(lengths), mesh, "model", page_size=ps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(ref_lse),
                               rtol=2e-5, atol=2e-6)

    # page_attn seam: the serving apply step accepts the distributed
    # implementation and produces the same logits (LSE merge is exact up
    # to fp reassociation)
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    pool = M.make_page_pool(cfg, 2, 64, page_size=8, total_pages=17, tp=4)
    table = np.zeros((2, 8), np.int32)
    table[0, :4] = [1, 2, 3, 4]
    table[1, :2] = [5, 6]
    pool["page_table"] = jnp.asarray(table)
    pool["lengths"] = jnp.asarray([20, 9], jnp.int32)
    tok = jnp.asarray([3, 7], jnp.int32)
    live = jnp.asarray([True, True])
    pidx = jnp.tile(jnp.asarray([[0, 1, -1]], jnp.int32)[None],
                    (cfg.n_layers, 2, 1))
    want = M.decode_step_paged_presel(params, cfg, tok, dict(pool), live,
                                      pidx, cfg.memory, page_size=8, tp=4)
    dist = functools.partial(distributed_paged_sparse_decode,
                             mesh=mesh, axis="model")

    def page_attn(q, kc, vc, p, lb, page_size):
        return dist(q, kc, vc, p, lb, page_size=page_size)

    got = M.decode_step_paged_presel(params, cfg, tok, dict(pool), live,
                                     pidx, cfg.memory, page_size=8, tp=4,
                                     page_attn=page_attn)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=2e-4, atol=2e-5)
