"""Sharding-rule properties across ALL 10 archs x both production meshes —
the static guard behind the 80-cell dry-run matrix: every sharded dimension
must be divisible by the product of its mesh axes (jit in_shardings reject
uneven splits)."""
import numpy as np
import jax
import pytest

from repro.configs import ARCHS, SHAPES, get_arch


class FakeMesh:
    """Shape-only stand-in so spec generation needs no real devices."""

    def __init__(self, shape_dict):
        self.shape = shape_dict
        self.axis_names = tuple(shape_dict)

    @property
    def devices(self):
        class _D:
            size = int(np.prod(list(self.shape.values())))
        d = _D()
        return d


MESHES = {
    "16x16": FakeMesh({"data": 16, "model": 16}),
    "2x16x16": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


def _check_divisible(structs, specs, mesh, where):
    flat_s = jax.tree_util.tree_flatten_with_path(structs)[0]
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index"))
    from jax.sharding import PartitionSpec
    flat_p = [p for p in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, PartitionSpec))]
    assert len(flat_s) == len(flat_p), where
    for (path, leaf), spec in zip(flat_s, flat_p):
        for dim, names in enumerate(spec):
            if names is None:
                continue
            names = (names,) if isinstance(names, str) else names
            ways = int(np.prod([mesh.shape[n] for n in names]))
            assert leaf.shape[dim] % ways == 0, (
                where, path, leaf.shape, dim, spec)


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divisible(arch, mesh_name):
    from repro.distributed import sharding as sh
    from repro.launch.specs import param_structs

    cfg = get_arch(arch)
    mesh = MESHES[mesh_name]
    structs = param_structs(cfg, tp=mesh.shape["model"])
    specs = sh.param_specs(structs, cfg, mesh)
    _check_divisible(structs, specs, mesh, (arch, mesh_name, "params"))


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_cache_specs_divisible(arch, shape_name):
    from repro.distributed import sharding as sh
    from repro.launch.specs import cache_structs

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape.kind != "decode":
        pytest.skip("caches only matter for decode shapes")
    mesh = MESHES["16x16"]
    structs = cache_structs(cfg, shape.global_batch, shape.seq_len,
                            tp=mesh.shape["model"])
    specs = sh.cache_specs(structs, cfg, shape, mesh)
    _check_divisible(structs, specs, mesh, (arch, shape_name, "caches"))


def test_fsdp_threshold():
    from repro.distributed import sharding as sh
    from repro.launch.specs import param_structs
    from jax.sharding import PartitionSpec

    mesh = MESHES["16x16"]
    big = get_arch("qwen2-vl-72b")
    small = get_arch("llama3.2-1b")
    specs_big = sh.param_specs(param_structs(big, 16), big, mesh)
    specs_small = sh.param_specs(param_structs(small, 16), small, mesh)
    has_data = lambda specs: any(
        "data" in str(s) for s in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, PartitionSpec)))
    assert has_data(specs_big)        # 72B: FSDP engaged
    assert not has_data(specs_small)  # 1.5B: TP only
