"""Multi-device tests (subprocess with 8 fake CPU devices — the main test
process must keep seeing exactly 1 device, DESIGN.md §6)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd="/tmp",
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_device_isolation():
    """This process sees exactly the device count IT was launched with
    (1 by default; CI runs the fast split with 2 for the hetero offload
    path) — a subprocess's XLA_FLAGS never leak back; subprocesses see 8."""
    import re
    import jax
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)",
                  os.environ.get("XLA_FLAGS", ""))
    assert jax.device_count() == (int(m.group(1)) if m else 1)
    out = _run("import jax; print(jax.device_count())")
    assert out.strip() == "8"


@pytest.mark.slow
def test_distributed_topk_and_decode_exact():
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
mesh = make_mesh((2,4), ("data","model"))
from repro.distributed.topk import distributed_relevancy_topk, distributed_sparse_decode
from repro.kernels import ref
rng = np.random.default_rng(0)
B,Hq,dk,S,k = 2,4,32,256,16
q = jnp.asarray(rng.standard_normal((B,Hq,dk)), jnp.float32)
keys = jnp.asarray(rng.standard_normal((B,S,dk)), jnp.float32)
w = jnp.abs(jnp.asarray(rng.standard_normal((B,Hq)), jnp.float32))
v1,i1 = distributed_relevancy_topk(q, keys, w, k, mesh, "model", block=64)
v2,i2 = ref.relevancy_topk(q, keys, w, k)
assert np.allclose(np.asarray(v1), np.asarray(v2), atol=1e-5)
v3,_ = distributed_relevancy_topk(q, keys, w, k, mesh, ("data","model"), block=32)
assert np.allclose(np.asarray(v3), np.asarray(v2), atol=1e-5)
KV,G,dh,ps = 2,2,32,8
q2 = jnp.asarray(rng.standard_normal((B,KV*G,dh)), jnp.float32)
kc = jnp.asarray(rng.standard_normal((B,S,KV,dh)), jnp.float32)
vc = jnp.asarray(rng.standard_normal((B,S,KV,dh)), jnp.float32)
pages = jnp.asarray(np.stack([rng.choice(S//ps,8,replace=False) for _ in range(B)]), jnp.int32)
length = jnp.asarray([S, S//2], jnp.int32)
o1 = distributed_sparse_decode(q2, kc, vc, pages, length, mesh, "model", page_size=ps)
o2,_ = ref.paged_decode_attention(q2, kc, vc, pages, ps, length)
assert np.abs(np.asarray(o1)-np.asarray(o2)).max() < 1e-4
# batch sharded over data (decode_32k layout)
o3 = distributed_sparse_decode(q2, kc, vc, pages, length, mesh, "model", page_size=ps, batch_axis="data")
assert np.abs(np.asarray(o3)-np.asarray(o2)).max() < 1e-4
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """One train step on a (2,4) mesh == the same step on 1 device."""
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh, use_mesh
from repro.configs import get_arch
from repro.models import init_params
from repro.train import make_train_step, init_opt_state, TrainConfig
from repro.distributed import sharding as sh
from repro.data import TokenStream

cfg = get_arch("llama3.2-1b").smoke()
params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
b = {k: jnp.asarray(v) for k, v in TokenStream(cfg.vocab_size, 32, 4, seed=0).next_batch().items()}
tc = TrainConfig(tp=4)
step = make_train_step(cfg, tc)

mesh = make_mesh((2,4), ("data","model"))
specs = sh.param_specs(params, cfg, mesh)
shards = sh.make_shardings(specs, mesh)
params_sh = jax.device_put(params, shards)
opt_sh = init_opt_state(params_sh)
opt_ref = init_opt_state(params)
# run the sharded step FIRST: device_put may alias replicated leaves, and
# the single-device step donates (deletes) its inputs.
with use_mesh(mesh):
    p2, _, st2 = jax.jit(step)(params_sh, opt_sh, b)
p_ref, _, st_ref = step(params, opt_ref, b)
assert abs(float(st_ref["loss"]) - float(st2["loss"])) < 2e-3, (st_ref["loss"], st2["loss"])
for a, c in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(c, np.float32), rtol=3e-2, atol=3e-3)
print("OK")
""")
    assert "OK" in out


def test_gpipe_pipeline_parallel():
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.distributed.pipeline_parallel import gpipe_forward, bubble_fraction
mesh = make_mesh((4,), ("pod",))
n_stages, M, mb, d = 4, 8, 2, 16
ws = jnp.asarray(np.random.default_rng(0).standard_normal((n_stages, d, d)) / 4, jnp.float32)
xs = jnp.asarray(np.random.default_rng(1).standard_normal((M, mb, d)), jnp.float32)
def group(w, x): return jnp.tanh(x @ w)
fn = gpipe_forward(group, mesh, "pod")
out = fn(ws, xs)
ref = xs
for s in range(n_stages):
    ref = jnp.tanh(ref @ ws[s])
assert np.abs(np.asarray(out) - np.asarray(ref)).max() < 1e-5
assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
print("OK")
""")
    assert "OK" in out


def test_checkpoint_elastic_reshard():
    """Checkpoint written from an 8-device mesh restores onto 4 devices."""
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed import checkpoint as ckpt
mesh8 = make_mesh((2,4), ("data","model"))
w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                   NamedSharding(mesh8, P("data","model")))
d = tempfile.mkdtemp()
ckpt.save(d, 1, {"w": w})
mesh4 = make_mesh((4,), ("model",))
tgt = NamedSharding(mesh4, P(None, "model"))
back = ckpt.restore(d, 1, {"w": jnp.zeros((8,8))}, shardings={"w": tgt})
assert back["w"].sharding == tgt
np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(w))
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_cell_executes():
    """The real dry-run entrypoint (512 placeholder devices) compiles a cell."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "llama3.2-1b",
         "--shape", "decode_32k", "--force"],
        env=env, cwd=os.path.join(SRC, "..") , capture_output=True, text=True,
        timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "1 ok, 0 failed" in out.stdout
    rec = json.load(open(os.path.join(
        SRC, "..", "experiments", "dryrun",
        "llama3.2-1b__decode_32k__16x16__baseline.json")))
    assert rec["ok"] and rec["roofline"]["bottleneck"] in (
        "compute", "memory", "collective")


@pytest.mark.slow
def test_cached_index_decode_matches_stateless():
    """§Perf iteration 3 correctness: the incremental index cache path
    (prepare-once) must produce the same attention output as the stateless
    distributed path that re-projects the whole context every step."""
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.core.methods import dsa
from repro.launch.mesh import make_mesh
mesh = make_mesh((2,4), ("data","model"))
cfg = get_arch("llama3.2-1b").smoke()
mem = cfg.memory.replace(top_k=32, index_heads=4, index_dim=32)
page = 8
rng = np.random.default_rng(0)
B, S = 2, 64
KV, hd, HP = cfg.n_kv_heads, cfg.hd, cfg.padded_heads(4)
kc = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
vc = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
q = jnp.asarray(rng.standard_normal((B, 1, HP, hd)), jnp.float32)
sp = jax.tree.map(lambda a: a[0], dsa.dsa_init(jax.random.PRNGKey(1), cfg, mem))
length = jnp.asarray(S, jnp.int32)
k_new = kc[:, S-1][:, None]  # the key written this step

stateless = dsa.make_sparse_fn_distributed(cfg, mem, mesh, axis="model", tp=4, page=page)
out_d = stateless(q, kc, vc, length, sp)

# prebuild the index cache from all but the newest key
k_idx = (kc.reshape(B, S, -1) @ sp["wk_idx"]).astype(jnp.float32)
k_idx = k_idx.at[:, S-1].set(0.0)
kidx_sum = k_idx.reshape(B, S // page, page, -1).sum(axis=2)
cached = dsa.make_sparse_fn_cached(cfg, mem, mesh, axis="model", tp=4, page=page)
out_c, sp_new = cached(q, kc, vc, length, {"p": sp, "kidx_sum": kidx_sum}, k_new=k_new)

err = np.abs(np.asarray(out_c, np.float32) - np.asarray(out_d, np.float32)).max()
assert err < 1e-4, err
# the update landed in exactly the right page
full = (kc.reshape(B, S, -1) @ sp["wk_idx"]).astype(jnp.float32)
full_sum = full.reshape(B, S // page, page, -1).sum(axis=2)
assert np.abs(np.asarray(sp_new["kidx_sum"]) - np.asarray(full_sum)).max() < 1e-3
print("OK")
""")
    assert "OK" in out
