"""Per-kernel allclose sweeps vs the pure-jnp oracles (ref.py), across
shapes and dtypes, in Pallas interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# relevancy_topk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,dk,S,k,block", [
    (1, 4, 16, 128, 8, 32),
    (2, 8, 32, 512, 32, 128),
    (3, 64, 128, 1024, 128, 256),   # DSA-like indexer shape
    (2, 4, 16, 96, 16, 64),         # non-power-of-two S (pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_relevancy_topk_exact(B, Hq, dk, S, k, block, dtype):
    q = _arr((B, Hq, dk), dtype)
    keys = _arr((B, S, dk), dtype)
    w = jnp.abs(_arr((B, Hq), jnp.float32))
    v1, i1 = ops.relevancy_topk(q, keys, w, k, block=block)
    v2, i2 = ref.relevancy_topk(q, keys, w, k)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=tol, atol=tol)
    # discrete outputs: compare as sets (ties may reorder)
    for b in range(B):
        assert set(np.asarray(i1[b]).tolist()) == set(np.asarray(i2[b]).tolist())


def test_relevancy_topk_approximate_recall():
    """c < min(k, block): approximate mode must keep high recall."""
    B, Hq, dk, S, k = 2, 8, 32, 2048, 64
    q, keys = _arr((B, Hq, dk)), _arr((B, S, dk))
    w = jnp.abs(_arr((B, Hq), jnp.float32))
    v1, i1 = ops.relevancy_topk(q, keys, w, k, block=256, c=48)
    _, i2 = ref.relevancy_topk(q, keys, w, k)
    recall = np.mean([
        len(set(np.asarray(i1[b]).tolist()) & set(np.asarray(i2[b]).tolist())) / k
        for b in range(B)])
    assert recall > 0.9, recall


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,KV,G,dh,S,ps,nsel", [
    (1, 1, 1, 32, 128, 16, 4),
    (2, 2, 4, 64, 512, 16, 8),
    (2, 8, 8, 128, 1024, 64, 8),    # GQA 64 heads / 8 kv
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(B, KV, G, dh, S, ps, nsel, dtype):
    Hq = KV * G
    q = _arr((B, Hq, dh), dtype)
    kc = _arr((B, S, KV, dh), dtype)
    vc = _arr((B, S, KV, dh), dtype)
    pages = jnp.asarray(
        np.stack([RNG.choice(S // ps, nsel, replace=False) for _ in range(B)]),
        jnp.int32)
    pages = pages.at[0, -1].set(-1)  # invalid page masking
    length = jnp.asarray(RNG.integers(S // 2, S + 1, B), jnp.int32)
    o1, l1 = ops.paged_decode_attention(q, kc, vc, pages, length, page_size=ps)
    o2, l2 = ref.paged_decode_attention(q, kc, vc, pages, ps, length)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=tol, atol=tol)


def test_lse_merge_equals_joint_attention():
    """Two disjoint half-contexts LSE-merged == attention over the union."""
    B, KV, G, dh, S, ps = 1, 2, 2, 32, 256, 16
    Hq = KV * G
    q, kc, vc = _arr((B, Hq, dh)), _arr((B, S, KV, dh)), _arr((B, S, KV, dh))
    all_pages = jnp.arange(S // ps, dtype=jnp.int32)[None]
    length = jnp.asarray([S], jnp.int32)
    o_all, _ = ref.paged_decode_attention(q, kc, vc, all_pages, ps, length)
    lo = all_pages[:, : S // ps // 2]
    hi = all_pages[:, S // ps // 2:]
    o1, l1 = ref.paged_decode_attention(q, kc, vc, lo, ps, length)
    o2, l2 = ref.paged_decode_attention(q, kc, vc, hi, ps, length)
    merged, _ = ops.lse_merge(jnp.stack([o1, o2]), jnp.stack([l1, l2]))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(o_all),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,KV,dh,bq,window", [
    (1, 128, 4, 4, 32, 64, 0),
    (2, 200, 8, 2, 64, 64, 0),      # GQA + ragged block
    (2, 256, 4, 4, 32, 64, 48),     # sliding window (Mixtral)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, KV, dh, bq, window, dtype):
    q, k, v = _arr((B, S, H, dh), dtype), _arr((B, S, KV, dh), dtype), \
        _arr((B, S, KV, dh), dtype)
    o1 = ops.flash_attention(q, k, v, bq=bq, bk=bq, window=window)
    o2 = ref.flash_attention(q, k, v, window=window or None)
    tol = 2e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# page pool + bm25
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,KV,dh,ps", [(1, 128, 2, 32, 16),
                                          (2, 512, 8, 64, 64)])
def test_page_minmax(B, S, KV, dh, ps):
    kc = _arr((B, S, KV, dh))
    mn1, mx1 = ops.page_minmax(kc, page_size=ps)
    mn2, mx2 = ref.page_minmax(kc, ps)
    np.testing.assert_allclose(np.asarray(mn1), np.asarray(mn2))
    np.testing.assert_allclose(np.asarray(mx1), np.asarray(mx2))


@pytest.mark.parametrize("B,D,T,k,block", [
    (1, 128, 8, 8, 64), (2, 1000, 16, 32, 256),   # non-pow2 doc count
])
def test_bm25_topk(B, D, T, k, block):
    tf = jnp.asarray(RNG.poisson(1.0, (B, D, T)), jnp.float32)
    dl = jnp.asarray(RNG.integers(20, 200, (B, D)), jnp.float32)
    idf = jnp.asarray(RNG.random((B, T)), jnp.float32)
    v1, i1 = ops.bm25_topk(tf, dl, idf, k, block=block)
    v2, i2 = ref.bm25_topk(tf, dl, idf, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-5, atol=1e-5)
