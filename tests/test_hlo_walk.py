"""Unit tests for the trip-count-aware HLO cost walker — the §Roofline
numbers stand on this being exact for scan/grad/remat programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_walk

L, N, B = 8, 128, 4
EXPECT_FWD = L * 2 * B * N * N  # flops of the scanned matmul chain


def _chain(remat: bool):
    def f(ws, x):
        def body(x, w):
            fn = (jax.checkpoint(lambda x, w: jnp.tanh(x @ w)) if remat
                  else (lambda x, w: jnp.tanh(x @ w)))
            return fn(x, w), None
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x)
    return f


@pytest.fixture(scope="module")
def arrs():
    return jnp.zeros((L, N, N), jnp.float32), jnp.zeros((B, N), jnp.float32)


def test_fwd_flops_exact(arrs):
    ws, x = arrs
    hlo = jax.jit(_chain(False)).lower(ws, x).compile().as_text()
    assert hlo_walk.walk(hlo).flops == pytest.approx(EXPECT_FWD, rel=1e-6)


def test_grad_flops_3x(arrs):
    ws, x = arrs
    hlo = jax.jit(jax.grad(_chain(False))).lower(ws, x).compile().as_text()
    assert hlo_walk.walk(hlo).flops == pytest.approx(3 * EXPECT_FWD, rel=1e-6)


def test_remat_grad_flops_4x(arrs):
    ws, x = arrs
    hlo = jax.jit(jax.grad(_chain(True))).lower(ws, x).compile().as_text()
    assert hlo_walk.walk(hlo).flops == pytest.approx(4 * EXPECT_FWD, rel=1e-6)


def test_nested_scan_trip_product(arrs):
    """cost_analysis single-counts nested scans; the walker must multiply."""
    ws, x = arrs
    outer = 5

    def f(ws, x):
        def o(x, _):
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, ws)
            return x, None
        x, _ = jax.lax.scan(o, x, None, length=outer)
        return x

    hlo = jax.jit(f).lower(ws, x).compile().as_text()
    assert hlo_walk.walk(hlo).flops == pytest.approx(outer * EXPECT_FWD,
                                                     rel=1e-6)


def test_trip_count_parse():
    hlo = jax.jit(lambda x: jax.lax.fori_loop(
        0, 17, lambda i, x: x * 1.5, x)).lower(
        jnp.zeros((4,))).compile().as_text()
    comps = hlo_walk.parse_computations(hlo)
    conds = [hlo_walk._attr_comp(i.rest, "condition")
             for c in comps.values() for i in c.instrs if i.op == "while"]
    assert conds and hlo_walk.trip_count(comps[conds[0]]) == 17


def test_shape_bytes():
    assert hlo_walk._spec_bytes("bf16[8,4]{1,0}") == 64
    assert hlo_walk._spec_bytes("(f32[2,2]{1,0}, s32[3]{0})") == 16 + 12
    assert hlo_walk._spec_bytes("pred[10]") == 10
