import os
import sys

# src/ layout import path (tests run with or without PYTHONPATH=src)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 CPU device
# (DESIGN.md §6). Multi-device tests spawn subprocesses that set the flag.
