import os
import random
import sys
import types

# src/ layout import path (tests run with or without PYTHONPATH=src)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — the suite must pass with whatever
# device count it was launched under: 1 (default) and 2 (CI's fast split,
# which exercises the hetero offload executor's real main/offload split).
# Many-device tests spawn subprocesses that set the flag themselves.


# ---------------------------------------------------------------------------
# hypothesis fallback shim
#
# The property tests (test_bitonic / test_pipeline / test_train) use the real
# hypothesis package when it is installed (CI installs the [test] extra from
# pyproject.toml). Hermetic environments without it get this minimal,
# deterministic example-drawing shim instead of failing collection outright.
# It covers exactly the API surface those tests use: @given, @settings,
# st.integers/floats/lists/booleans and @st.composite.
# ---------------------------------------------------------------------------

try:  # pragma: no cover - exercised only when hypothesis is absent
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng):
            return self._draw(rng)

    def _integers(lo, hi):
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def _floats(lo, hi, width=64, allow_subnormal=True):
        import numpy as _np

        def draw(rng):
            x = rng.uniform(lo, hi)
            return float(_np.float32(x)) if width == 32 else x

        return _Strategy(draw)

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _lists(elem, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elem.draw(rng) for _ in range(rng.randint(min_size, max_size))])

    def _composite(fn):
        def build(*args, **kwargs):
            def draw_example(rng):
                return fn(lambda s: s.draw(rng), *args, **kwargs)
            return _Strategy(draw_example)
        return build

    def _given(*strategies):
        def deco(f):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", 10)
                rng = random.Random(f.__qualname__)  # deterministic per test
                for _ in range(n):
                    vals = [s.draw(rng) for s in strategies]
                    f(*args, *vals, **kwargs)
            # NOT functools.wraps: pytest must see the zero-arg signature,
            # not the strategy parameters (it would treat them as fixtures)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper._shim_given = True
            return wrapper
        return deco

    def _settings(max_examples=10, deadline=None, **_kw):
        def deco(f):
            f._shim_max_examples = max_examples
            return f
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.lists = _lists
    _st.composite = _composite

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
