"""Sequence-parallel apply on the main mesh (``ServeConfig(main_mesh=N)``).

Load-bearing properties:

  * pooled decode with ``main_mesh=2`` emits token streams BIT-IDENTICAL
    to ``main_mesh=1`` — and to the fully synchronous inline-retrieval
    schedule — for dsa / seer / lserve on a mixed pool with a
    retrieval-enabled slot, both standalone and composed with
    ``offload_shards=2`` (selection AND apply sharded, the paper's
    Fig. 6a end to end);
  * the scheduler path (chunked prefill, staggered completion) holds the
    same bit-match, and the DENSE fallback branch of the traced cond runs
    through the same sequence-parallel seam (a window crossing mid-decode
    exercises both branches on the mesh);
  * pow2-bucketed decode views stay aligned to the shard granularity
    ``main_mesh * page_size`` — the regression for the bucket size that
    used to trip ``distributed_paged_sparse_decode``'s divisibility
    assert;
  * the unified LSE-merge core matches the single-device paged attention
    for duplicate-free page ids with ``-1`` holes anywhere and ragged
    per-slot lengths (hypothesis property, shim-compatible), through the
    ONE shard body the dense wrapper shares.

CI runs this file under 2 host devices (the fast split) and in the
dedicated ``main-mesh`` leg of the ``test-sharded`` matrix under 4 — the
full 2-mesh + 2-selection-shard topology; with one device the mesh clamps
to a single device and every property still holds.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch
from repro.data import build_corpus
from repro.distributed.topk import (distributed_paged_sparse_decode,
                                    distributed_sparse_decode)
from repro.kernels import ops
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.retrieval import RetrievalConfig
from repro.serving import Engine, OffloadConfig, Request, ServeConfig, \
    Scheduler


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    corpus = build_corpus(48, retrieval_vocab=128, doc_max=8,
                          gen_vocab=cfg.vocab_size, embed_dim=16, seed=0)
    return cfg, params, corpus


def _drain(eng, n_steps):
    got = {}
    for _ in range(n_steps):
        for rid, _slot, tok in eng.poll():
            got.setdefault(rid, []).append(tok)
    return got


def _rcfg(corpus, mode):
    return RetrievalConfig(mode=mode, kind="rag", corpus=corpus, k=2,
                           trigger="flare", tau=1.1, min_interval=3,
                           max_retrievals=1, query_window=6)


# ---------------------------------------------------------------------------
# serving bit-exactness: mesh=2 == mesh=1 == inline retrieval, incl. the
# combined selection x apply topology
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["dsa", "seer", "lserve"])
def test_main_mesh_bitmatches_single(setup, method):
    """Mixed pool (one retrieval-enabled slot + one sparse slot): the
    2-device apply mesh serves the same tokens as the single-device apply,
    standalone AND composed with 2 selection shards, and the merged
    selection still reaches the mesh as indices only."""
    cfg, params, corpus = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (16, 24)]
    streams, events = {}, {}
    mesh_eng = None
    for off, rmode, shards, mesh_n in (("sync", "inline", 1, 1),
                                       ("sync", "sync", 1, 2),
                                       ("overlap", "overlap", 2, 2)):
        sc = ServeConfig(max_len=128, n_slots=2, method=method, tp=4,
                         page=8, kv_page_size=16,
                         offload_cfg=OffloadConfig(
                             mode=off, shards=shards, main_mesh=mesh_n,
                             validate=(off == "overlap")),
                         retrieval=_rcfg(corpus, rmode))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, 6, retrieval=(i == 0)))
        key = (off, rmode, shards, mesh_n)
        streams[key] = _drain(eng, 24)
        events[key] = [(e["slot"], tuple(e["ids"])) for e in
                       eng.retrieval.events]
        assert events[key], "no retrieval fired"
        assert eng.pool.pages_in_use() == 0
        if mesh_n > 1:
            mesh_eng = eng
    first = streams[("sync", "inline", 1, 1)]
    assert all(s == first for s in streams.values())
    assert len(set(map(tuple, events.values()))) == 1

    # the apply side saw only merged page indices (up link) — 8 B per
    # candidate per step PER MESH COPY (replication to N mesh devices
    # moves N physical copies; the ledger counts every one)
    hx = mesh_eng.hetero
    L, B = cfg.n_layers, 2
    n_copies = hx.main_mesh.size
    for led, shard in zip(hx.ledgers, hx.shards):
        assert led.up_bytes <= led.steps * 8 * L * B * shard.n_part * \
            n_copies
    rep = hx.report()
    if jax.device_count() >= 2:
        assert len(set(rep["devices"]["main_mesh"])) == 2


def test_main_mesh_under_scheduler(setup):
    """Chunked admission + staggered completion through the Scheduler:
    the combined offload_shards=2 + main_mesh=2 topology bit-matches the
    synchronous single-device executor end to end."""
    cfg, params, _ = setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (10, 40, 16, 33)]
    streams = {}
    for off, shards, mesh_n in (("sync", 1, 1), ("overlap", 2, 2)):
        sc = ServeConfig(max_len=128, n_slots=2, method="dsa", tp=4, page=8,
                         kv_page_size=16, prefill_chunk=16,
                         chunk_threshold=32,
                         offload_cfg=OffloadConfig(mode=off, shards=shards,
                                                   main_mesh=mesh_n))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
        sch = Scheduler(eng, prefill_token_budget=32)
        rids = [sch.submit(p, max_new=4) for p in prompts]
        done = sch.run()
        assert sorted(done) == sorted(rids)
        streams[(off, shards, mesh_n)] = {r: done[r].tokens for r in done}
        assert eng.pool.pages_in_use() == 0
    assert streams[("sync", 1, 1)] == streams[("overlap", 2, 2)]


def test_main_mesh_dense_fallback_window(setup):
    """The dynamic-fallback dense branch also runs on the mesh: a run that
    starts BELOW min_context (dense apply) and crosses into the sparse
    window mid-decode exercises both cond branches sequence-parallel and
    still bit-matches the single-device engine."""
    cfg, params, _ = setup
    mem = cfg.memory.replace(method="dsa", min_context=48)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (40, 16)]                 # slot 0 crosses 48 mid-run
    streams = {}
    for mesh_n in (1, 2):
        sc = ServeConfig(max_len=128, n_slots=2, method="dsa", tp=4,
                         page=8, kv_page_size=16,
                         offload_cfg=OffloadConfig(mode="sync",
                                                   main_mesh=mesh_n))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0), mem=mem)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, 12))
        streams[mesh_n] = _drain(eng, 14)
        assert eng.hetero.profiler.offload_steps > 0, \
            "run never entered the sparse window"
    assert streams[1] == streams[2]
    assert all(len(v) == 12 for v in streams[1].values())


# ---------------------------------------------------------------------------
# bucket / shard granularity (regression: satellite of the mesh apply)
# ---------------------------------------------------------------------------


def test_view_buckets_align_to_mesh_granularity(setup):
    """pow2-bucketed view lengths are multiples of main_mesh * page_size.
    Pre-fix, the granule ignored the mesh: the smallest dsa bucket was 16
    tokens (lcm of page=8 and kv_page=16), which trips the shard assert
    ``S % (n_shards * page_size) == 0`` at main_mesh=4 — 16 % 32 != 0."""
    cfg, params, _ = setup
    sc = ServeConfig(max_len=512, n_slots=2, method="dsa", tp=4, page=8,
                     kv_page_size=16,
                     offload_cfg=OffloadConfig(mode="sync", main_mesh=4))
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    ps = eng.hetero.sel.page
    old_gran = eng._gran // sc.main_mesh          # what PR 4 would bucket by
    assert old_gran % (sc.main_mesh * ps) != 0    # the tripping bucket size
    for needed in range(1, eng.sc.max_len + 1, 7):
        vl = eng._view_len(needed)
        assert vl % (sc.main_mesh * ps) == 0, (needed, vl)
        assert vl % (sc.main_mesh * sc.kv_page_size) == 0, (needed, vl)

    # functional: the smallest bucket actually decodes through the mesh
    # (pre-fix this step raised in distributed_paged_sparse_decode)
    rng = np.random.default_rng(0)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, size=8), 4))
    got = _drain(eng, 6)
    assert len(got[0]) == 4


def test_unaligned_view_trips_shard_assert():
    """The contract the engine alignment protects: a view that is NOT a
    multiple of n_shards * page_size is rejected loudly, not mis-sharded."""
    if jax.device_count() < 2:
        pytest.skip("needs a >=2-device mesh for a real shard count")
    mesh = make_mesh((2,), ("seq",))
    q = jnp.zeros((1, 2, 8), jnp.float32)
    kc = jnp.zeros((1, 24, 1, 8), jnp.float32)    # 24 % (2 * 8) != 0
    with pytest.raises(AssertionError):
        distributed_paged_sparse_decode(
            q, kc, kc, jnp.zeros((1, 2), jnp.int32),
            jnp.zeros((1,), jnp.int32), mesh, "seq", page_size=8)


# ---------------------------------------------------------------------------
# unified LSE-merge core == single-device reference (property)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.booleans())
def test_lse_merge_core_matches_reference(seed, B, holes):
    """Duplicate-free page ids with -1 holes anywhere + ragged per-slot
    lengths through the unified (out, lse) core == ``ops.
    paged_decode_attention`` on one device — and the dense wrapper built on
    the SAME shard body agrees with the core's out to the last bit.

    Every slot keeps at least one LIVE pick (the page holding its last
    live token) — the serving contract: ``decode_step_paged_presel``
    force-includes the current page, so an EFFECTIVELY EMPTY selection
    (all -1 / all picks past the live region) never reaches the apply.
    On an empty selection the softmax is degenerate and single-device vs
    shard-merged garbage legitimately differ."""
    rng = np.random.default_rng(seed)
    ps, Hq, KV, dh = 8, 4, 2, 16
    mesh = make_mesh((jax.device_count(),), ("seq",))
    n_sh = jax.device_count()
    S = int(rng.integers(2, 6)) * n_sh * ps       # shard-aligned view
    P = S // ps
    lengths = rng.integers(1, S + 1, size=B).astype(np.int32)
    k = np.zeros((B, S, KV, dh), np.float32)
    v = np.zeros((B, S, KV, dh), np.float32)
    for b in range(B):   # zero-page invariant: dead region is exact zeros
        k[b, : lengths[b]] = rng.normal(size=(lengths[b], KV, dh))
        v[b, : lengths[b]] = rng.normal(size=(lengths[b], KV, dh))
    q = rng.normal(size=(B, Hq, dh)).astype(np.float32)
    n_pick = int(rng.integers(1, P + 1))
    pids = np.full((B, n_pick + 1), -1, np.int32)
    for b in range(B):                            # duplicate-free picks
        cur = (lengths[b] - 1) // ps              # page of last live token
        picks = rng.choice(P, size=n_pick, replace=False)
        if holes:
            picks = np.where(rng.random(n_pick) < 0.4, -1, picks)
        picks = np.where(picks == cur, -1, picks)  # engine recency dedup
        pids[b, :n_pick] = picks
        pids[b, n_pick] = cur                      # force-included page
    args = (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(pids), jnp.asarray(lengths))
    ref_out, ref_lse = ops.paged_decode_attention(*args[:3], args[3],
                                                  args[4], page_size=ps)
    out, lse = distributed_paged_sparse_decode(*args, mesh, "seq",
                                               page_size=ps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(ref_lse),
                               rtol=2e-5, atol=2e-6)
    dense = distributed_sparse_decode(*args, mesh, "seq", page_size=ps)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(out))
