"""Serving-integrated retrieval subsystem (src/repro/retrieval).

Load-bearing properties:

  * the corpus store answers fused-BM25 queries identically to the inline
    ``rag.bm25_retrieve`` path, and incremental ingest appends documents
    without re-jitting the query/ingest functions (capacity permitting);
  * FLARE/DRAGIN triggers firing MID-DECODE on pooled slots splice the
    retrieved payload through the chunked-extend path, preserving the
    paged pool's zero-page invariant;
  * every scheduling mode — inline (the stop-retrieve-resume oracle),
    sync (offload device, serialized), overlap (retrieval under decode) —
    emits BIT-IDENTICAL token streams with identical retrieved doc ids /
    embeddings, for dynamic RAG and for MaC memory banks, including mixed
    pools where retrieval slots share the pool with sparse-attention
    (hetero-offloaded) slots;
  * the inline schedule itself matches a hand-rolled stop-retrieve-resume
    oracle built from per-request ``generate`` over the doc-augmented
    prompt.

CI runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=2``
so sync/overlap place the corpus/banks on a REAL second device; with one
device the service still runs (transfers degenerate to no-ops).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.methods import mac as mac_m
from repro.core.methods import offload_stages, rag as rag_m
from repro.data import build_corpus, sample_queries
from repro.hetero.select import make_offload_select
from repro.retrieval import RetrievalConfig, RetrievalService
from repro.serving import Engine, OffloadConfig, Request, ServeConfig, \
    Scheduler

MODES = ("inline", "sync", "overlap")


@pytest.fixture(scope="module")
def setup():
    from repro.models import init_params

    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    corpus = build_corpus(48, retrieval_vocab=128, doc_max=8,
                          gen_vocab=cfg.vocab_size, embed_dim=16, seed=0)
    return cfg, params, corpus


def _free_pages_zero(pool) -> bool:
    """Every page on the free list (and the reserved page 0) must be zero."""
    idx = np.asarray([0] + pool.free, np.int32)
    k = np.asarray(pool.device["k_pages"][:, idx], np.float32)
    v = np.asarray(pool.device["v_pages"][:, idx], np.float32)
    return not k.any() and not v.any()


def _drain(eng, n_steps):
    got = {}
    for _ in range(n_steps):
        for rid, _slot, tok in eng.poll():
            got.setdefault(rid, []).append(tok)
    return got


def _submit_all(eng, prompts, max_new, retrieval=None):
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new,
                           retrieval=None if retrieval is None
                           else retrieval[i]))


def _rcfg(corpus, mode, **kw):
    base = dict(kind="rag", corpus=corpus, k=2, trigger="flare", tau=1.1,
                min_interval=3, max_retrievals=1, query_window=6)
    base.update(kw)
    return RetrievalConfig(mode=mode, **base)


# ---------------------------------------------------------------------------
# corpus store / service
# ---------------------------------------------------------------------------


def test_store_matches_inline_bm25(setup):
    """The device-resident store's fused query returns the same doc ids as
    the inline per-request BM25 path."""
    _, _, corpus = setup
    svc = RetrievalService(corpus, k=4)
    q = np.asarray(sample_queries(corpus, 3, 6, seed=1))
    ids, spans = svc.collect(svc.query(q))
    _, ref = rag_m.bm25_retrieve(corpus, jnp.asarray(q), k=4, fused=True)
    np.testing.assert_array_equal(ids, np.asarray(ref))
    # spans are the concatenated true-length token payloads of the docs
    doc_toks = np.asarray(corpus.doc_tokens)
    doc_len = np.asarray(corpus.doc_len, np.int32)
    want = np.concatenate([doc_toks[i, : doc_len[i]] for i in ids[0]])
    np.testing.assert_array_equal(spans[0], want)


def test_incremental_ingest_appends_without_rejit(setup):
    """New docs append through the fixed-block jitted path: no re-jit of
    select/ingest while the capacity holds; queries see the new docs."""
    _, _, corpus = setup
    svc = RetrievalService(corpus, k=4, capacity=256)
    q = np.asarray(sample_queries(corpus, 2, 6, seed=2))
    svc.collect(svc.query(q))
    sel_cache = svc._select_jit._cache_size()
    extra = build_corpus(40, retrieval_vocab=128, doc_max=8,
                         gen_vocab=512, embed_dim=16, seed=11)
    svc.ingest(extra)
    svc.ingest(rag_m.corpus_slice(extra, 0, 16))
    assert svc.n_docs == corpus.n_docs + 56
    ids, _ = svc.collect(svc.query(q))
    assert svc._select_jit._cache_size() == sel_cache
    assert svc._ingest_jit._cache_size() == 1
    assert (ids < svc.n_docs).all() and (ids >= 0).all()
    # a query biased at the ingested docs can retrieve them
    q2 = np.asarray(sample_queries(extra, 2, 6, seed=3))
    ids2, _ = svc.collect(svc.query(q2))
    assert (ids2 >= corpus.n_docs).any()


def test_ingest_grow_and_partial_block():
    """Arena growth must pad only the doc-axis arrays (df/idf run over the
    retrieval vocab, which can equal the capacity by shape), and a partial
    final block at the capacity edge must append without growing."""
    c = build_corpus(128, retrieval_vocab=128, doc_max=8, gen_vocab=512,
                     seed=2)
    svc = RetrievalService(c, k=4)          # capacity == vocab == 128
    svc.ingest(rag_m.corpus_slice(c, 0, 40))
    assert svc.capacity == 256 and svc.n_docs == 168
    ids, _ = svc.collect(svc.query(
        np.asarray(sample_queries(c, 2, 6, seed=4))))
    assert (ids >= 0).all() and (ids < svc.n_docs).all()
    c2 = build_corpus(120, retrieval_vocab=128, doc_max=8, gen_vocab=512,
                      seed=3)
    s2 = RetrievalService(c2, k=4, capacity=128, ingest_block=64)
    s2.ingest(rag_m.corpus_slice(c2, 0, 8))  # 120 + 8 == capacity: no grow
    assert s2.capacity == 128 and s2.n_docs == 128
    np.testing.assert_array_equal(np.asarray(s2.state["tf"][120:]),
                                  np.asarray(c2.tf[:8]))
    np.testing.assert_array_equal(np.asarray(s2.state["tf"][:120]),
                                  np.asarray(c2.tf))


def test_make_offload_select_covers_all_declarers(setup):
    """Every method that declares OFFLOAD_STAGES has an offload-side
    implementation reachable through make_offload_select."""
    cfg, _, corpus = setup
    declarers = [m for m in ("dsa", "seer", "lserve", "rag", "mac",
                             "memagent", "ttt", "none")
                 if offload_stages(m)]
    assert set(declarers) == {"dsa", "seer", "lserve", "rag", "mac"}
    for m in declarers:
        sel = make_offload_select(
            m, cfg, cfg.memory, dsa_page=8, n_slots=2, max_len=64,
            corpus=corpus, rag_k=3,
            mac=mac_m.MacConfig(segment_len=16, memory_slots=4,
                                retrieve_k=2))
        assert sel.method == m and sel.n_sel >= 1


# ---------------------------------------------------------------------------
# dynamic-RAG triggers in the serving loop
# ---------------------------------------------------------------------------


def test_rag_trigger_modes_bitmatch(setup):
    """FLARE firing mid-decode on pooled slots: doc splice through the
    chunked-extend path; inline == sync == overlap token-for-token with the
    same retrieved doc ids; pages come back clean."""
    cfg, params, corpus = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (16, 9)]
    streams, events = {}, {}
    for mode in MODES:
        sc = ServeConfig(max_len=128, n_slots=2, method="none", tp=4,
                         kv_page_size=16,
                         retrieval=_rcfg(corpus, mode, validate=True))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
        _submit_all(eng, prompts, 8)
        streams[mode] = _drain(eng, 26)
        events[mode] = [(e["slot"], tuple(e["ids"]), e["spliced"])
                        for e in eng.retrieval.events]
        assert len(events[mode]) == 2          # one retrieval per slot
        assert eng.pool.pages_in_use() == 0
        assert _free_pages_zero(eng.pool)      # zero-page invariant
    assert streams["inline"] == streams["sync"] == streams["overlap"]
    assert events["inline"] == events["sync"] == events["overlap"]


def test_inline_matches_stop_retrieve_resume_oracle(setup):
    """The pooled inline schedule == a hand-rolled oracle: stop at the
    trigger, retrieve with the standalone BM25 path, append the docs to the
    context, regenerate the pending token, resume per-request decode."""
    cfg, params, corpus = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
    max_new = 10
    sc = ServeConfig(max_len=128, n_slots=1, method="none", tp=4,
                     kv_page_size=16,
                     retrieval=_rcfg(corpus, "inline", min_interval=4))
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    eng.submit(Request(0, prompt, max_new))
    stream = _drain(eng, 30)[0]
    assert len(stream) == max_new
    [event] = eng.retrieval.events
    n_before = event["hist_len"] - len(prompt)   # tokens fed pre-trigger
    # oracle: per-slot window query -> standalone retrieval -> doc append
    ctx = np.concatenate([prompt, np.asarray(stream[:n_before], np.int32)])
    q = (ctx[-6:] % corpus.tf.shape[1]).astype(np.int32)
    _, ids = rag_m.bm25_retrieve(corpus, jnp.asarray(q)[None], k=2,
                                 fused=True)
    np.testing.assert_array_equal(np.asarray(ids[0]), event["ids"])
    doc_toks = np.asarray(corpus.doc_tokens)
    doc_len = np.asarray(corpus.doc_len, np.int32)
    span = np.concatenate([doc_toks[i, : doc_len[i]]
                           for i in np.asarray(ids[0])])
    prompt2 = np.concatenate([ctx, span]).astype(np.int32)
    # resume: per-request generate over the doc-augmented context
    eng2 = Engine(cfg, params, ServeConfig(max_len=128, n_slots=1,
                                           method="none", tp=4),
                  key=jax.random.PRNGKey(0))
    cont = eng2.generate(jnp.asarray(prompt2)[None],
                         max_new - n_before)[0]
    np.testing.assert_array_equal(np.asarray(stream[n_before:]), cont)


def test_trigger_gating(setup):
    """tau below any confidence never fires; the per-request retrieval
    budget and the per-request opt-out are honored."""
    cfg, params, corpus = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
               for _ in range(2)]
    sc = ServeConfig(max_len=128, n_slots=2, method="none", tp=4,
                     kv_page_size=16,
                     retrieval=_rcfg(corpus, "inline", tau=0.0))
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    _submit_all(eng, prompts, 6)
    _drain(eng, 10)
    assert eng.retrieval.events == []          # never fires at tau=0
    sc2 = ServeConfig(max_len=128, n_slots=2, method="none", tp=4,
                      kv_page_size=16,
                      retrieval=_rcfg(corpus, "inline", tau=1.1,
                                      min_interval=2, max_retrievals=2))
    eng2 = Engine(cfg, params, sc2, key=jax.random.PRNGKey(0))
    _submit_all(eng2, prompts, 10, retrieval=[True, False])
    _drain(eng2, 40)
    per_slot = {}
    for e in eng2.retrieval.events:
        per_slot[e["slot"]] = per_slot.get(e["slot"], 0) + 1
    assert per_slot.get(0, 0) == 2             # budget reached
    assert 1 not in per_slot                   # opted out


# ---------------------------------------------------------------------------
# MaC memory-bank service
# ---------------------------------------------------------------------------


def test_mac_bank_modes_bitmatch(setup):
    """Segment summaries pushed at page boundaries, retrieved embeddings
    spliced through the same chunked path: all three modes bit-match and
    report the same retrieved bank indices."""
    cfg, params, _ = setup
    mc = mac_m.MacConfig(segment_len=16, memory_slots=4, retrieve_k=2)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (40, 22)]
    streams, events = {}, {}
    for mode in MODES:
        rcfg = RetrievalConfig(kind="mac", mode=mode, mac=mc,
                               trigger="flare", tau=1.1, min_interval=2,
                               max_retrievals=2, query_window=8,
                               validate=True)
        sc = ServeConfig(max_len=128, n_slots=2, method="none", tp=4,
                         kv_page_size=16, retrieval=rcfg)
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
        _submit_all(eng, prompts, 8)
        streams[mode] = _drain(eng, 34)
        events[mode] = [(e["slot"], tuple(e["ids"])) for e in
                        eng.retrieval.events]
        assert events[mode], "no MaC retrieval fired"
        # prompt segments were summarized at admission (40 tokens -> 2)
        assert eng.pool.pages_in_use() == 0
        assert _free_pages_zero(eng.pool)
    assert streams["inline"] == streams["sync"] == streams["overlap"]
    assert events["inline"] == events["sync"] == events["overlap"]


# ---------------------------------------------------------------------------
# mixed pool: retrieval slots + hetero-offloaded sparse-attention slots
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["dsa", "lserve"])
def test_mixed_pool_with_hetero_offload(setup, method):
    """A retrieval-enabled slot and a sparse-attention slot share the paged
    pool while the hetero executor offloads selection: the fully overlapped
    configuration bit-matches the fully synchronous one."""
    cfg, params, corpus = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (16, 24)]
    streams = {}
    for off, rmode in (("sync", "inline"), ("overlap", "overlap")):
        sc = ServeConfig(max_len=128, n_slots=2, method=method, tp=4,
                         page=8, kv_page_size=16,
                         offload_cfg=OffloadConfig(mode=off),
                         retrieval=_rcfg(corpus, rmode))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
        _submit_all(eng, prompts, 6, retrieval=[True, False])
        streams[(off, rmode)] = _drain(eng, 24)
        assert eng.retrieval.events and \
            eng.retrieval.events[0]["slot"] == 0
        assert eng.hetero.profiler.offload_steps > 0
        assert eng.pool.pages_in_use() == 0
        assert _free_pages_zero(eng.pool)
    assert streams[("sync", "inline")] == streams[("overlap", "overlap")]


# ---------------------------------------------------------------------------
# scheduler end-to-end
# ---------------------------------------------------------------------------


def test_scheduler_serves_retrieval_requests(setup):
    """Overlapped retrieval under the scheduler: paused slots don't trip the
    starvation brake, all requests finish, DRAGIN triggers fire."""
    cfg, params, corpus = setup
    rng = np.random.default_rng(9)
    rcfg = _rcfg(corpus, "overlap", trigger="dragin", tau=0.0,
                 min_interval=4)
    sc = ServeConfig(max_len=128, n_slots=2, method="none", tp=4,
                     kv_page_size=16, prefill_chunk=16, chunk_threshold=32,
                     retrieval=rcfg)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    sch = Scheduler(eng, prefill_token_budget=32)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (10, 40, 16)]
    rids = [sch.submit(p, max_new=6) for p in prompts]
    done = sch.run()
    assert sorted(done) == sorted(rids)
    assert all(len(done[r].tokens) == 6 for r in rids)
    assert eng.retrieval.events
    assert eng.pool.pages_in_use() == 0
    assert _free_pages_zero(eng.pool)
