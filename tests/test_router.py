"""Fleet serving: the async router over Engine replicas (serving/router).

Load-bearing properties:

  * FLEET BIT-MATCH: a 2-replica router serving a mixed batch (dense +
    sparse + retrieval-enabled requests) returns, per request, EXACTLY
    the tokens a single engine's ``generate()`` / submit+drain produces —
    replication, device pinning and interleaved polling must not change
    results.  Covered for the plain pool, the retrieval splice, and the
    hetero-offload topology (the PR-3/PR-4 slots behind the router).
  * SESSION AFFINITY: every request of a session lands on one replica;
    different sessions spread by least-load.
  * SHARED CORPUS: the fleet holds ONE RetrievalService — documents
    ingested through the router are visible to every replica's triggers,
    and a replica pair serves identical splices to a single engine using
    the same service.

CI runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count
=4`` (the ``router`` leg: 2 replicas x 2 devices each); on fewer devices
the replica groups overlap and every property still holds.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_arch
from repro.data import build_corpus
from repro.hetero import pick_devices_replicas
from repro.models import init_params
from repro.retrieval import RetrievalConfig
from repro.serving import Engine, OffloadConfig, Request, Router, \
    ServeConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    corpus = build_corpus(64, retrieval_vocab=128, doc_max=8,
                          gen_vocab=cfg.vocab_size, embed_dim=16, seed=0)
    return cfg, params, corpus


def _rcfg(corpus):
    return RetrievalConfig(mode="sync", kind="rag", corpus=corpus, k=2,
                           trigger="flare", tau=1.1, min_interval=3,
                           max_retrievals=1, query_window=6)


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in sizes]


def test_replica_device_groups():
    """Replica groups partition the local devices contiguously; with fewer
    devices than replicas the groups round-robin (always non-empty)."""
    devs = jax.devices()
    groups = pick_devices_replicas(2)
    assert len(groups) == 2 and all(groups)
    if len(devs) >= 2:
        assert not set(groups[0]) & set(groups[1])
        assert len(groups[0]) + len(groups[1]) <= len(devs)
    groups = pick_devices_replicas(len(devs) + 1)
    assert len(groups) == len(devs) + 1 and all(groups)


def test_router_bitmatches_single_engine(setup):
    """Mixed dense + dsa-overridden + retrieval traffic through 2 replicas
    == the same requests through one engine, token for token."""
    cfg, params, corpus = setup
    sc = ServeConfig(max_len=128, n_slots=2, method="dsa", tp=4, page=8,
                     kv_page_size=16, retrieval=_rcfg(corpus))
    prompts = _prompts(cfg, (16, 24, 9, 32, 12, 20), seed=1)
    reqs = [Request(i, p, 6, retrieval=(i % 3 == 0))
            for i, p in enumerate(prompts)]

    ref_eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    refs = {}
    for r in reqs:           # one at a time: the per-request oracle
        ref_eng.submit(r)
        ref_eng.drain()
        refs[r.rid] = list(ref_eng.done.pop(r.rid).tokens)

    router = Router.build(cfg, params, sc, n_replicas=2,
                          key=jax.random.PRNGKey(0))
    assert len(router.replicas) == 2
    assert router.service is not None            # ONE corpus for the fleet
    svcs = {id(r.engine.retrieval.service) for r in router.replicas}
    assert svcs == {id(router.service)}
    hs = [router.submit(r) for r in reqs]
    done = router.drain()
    assert sorted(done) == sorted(r.rid for r in reqs)
    for h in hs:
        assert h.done and h.replica is not None
        np.testing.assert_array_equal(np.asarray(h.tokens),
                                      np.asarray(refs[h.rid]))
        assert h.ttft_s() is not None and h.ttft_s() >= 0
    # both replicas actually served
    assert {h.replica for h in hs} == {0, 1}


def test_router_bitmatches_with_hetero_offload(setup):
    """The offload topology behind the router: each replica runs the
    2-phase offload executor on its own device group and still serves the
    single-engine streams."""
    cfg, params, _ = setup
    sc = ServeConfig(max_len=64, n_slots=2, method="dsa", tp=4, page=8,
                     kv_page_size=16,
                     offload_cfg=OffloadConfig(mode="overlap"))
    prompts = _prompts(cfg, (16, 9, 24, 12), seed=2)
    ref_eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    refs = [ref_eng.generate(p[None], 5)[0] for p in prompts]

    router = Router.build(cfg, params, sc, n_replicas=2,
                          key=jax.random.PRNGKey(0))
    hs = [router.submit(Request(i, p, 5)) for i, p in enumerate(prompts)]
    router.drain()
    for h, want in zip(hs, refs):
        assert h.done
        np.testing.assert_array_equal(np.asarray(h.tokens, np.int32), want)
    for r in router.replicas:
        assert r.engine.pool.pages_in_use() == 0


def test_session_affinity_and_load_balance(setup):
    """All requests of one session stick to one replica; sessionless
    traffic spreads to the least-loaded replica."""
    cfg, params, _ = setup
    sc = ServeConfig(max_len=64, n_slots=2, method="none", tp=4,
                     kv_page_size=16)
    router = Router.build(cfg, params, sc, n_replicas=2,
                          key=jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (8, 8, 8, 8, 8, 8), seed=3)
    sessions = ["a", "b", "a", None, "b", "a"]
    hs = [router.submit(Request(i, p, 3, session=s))
          for i, (p, s) in enumerate(zip(prompts, sessions))]
    by_session = {}
    for h, s in zip(hs, sessions):
        if s is not None:
            by_session.setdefault(s, set()).add(h.replica)
    assert all(len(v) == 1 for v in by_session.values())
    assert len({h.replica for h in hs}) == 2     # load actually spread
    done = router.drain()
    assert len(done) == len(hs) and all(h.done for h in hs)
    rep = router.report()
    assert rep["requests_done"] == 6 and rep["sessions"] == 2
    assert all(r["polls"] > 0 for r in rep["replicas"])


def test_method_override_pins_replica(setup):
    """A heterogeneous fleet (none + dsa) routes ``method_overrides
    ['method']`` pins to the matching replica."""
    cfg, params, _ = setup
    base = dict(max_len=64, n_slots=2, tp=4, kv_page_size=16)
    cfgs = [ServeConfig(method="none", **base),
            ServeConfig(method="dsa", page=8, **base)]
    router = Router.build(cfg, params, cfgs, key=jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (8, 8, 8, 8), seed=4)
    hs = [router.submit(Request(i, p, 3,
                                method_overrides={"method": m}))
          for i, (p, m) in enumerate(zip(prompts,
                                         ["dsa", "none", "dsa", "none"]))]
    assert [h.replica for h in hs] == [1, 0, 1, 0]
    router.drain()
    assert all(h.done for h in hs)


def test_shared_corpus_ingest_visible_to_all_replicas(setup):
    """Documents ingested through the router join the one shared corpus;
    a replica pair using that corpus serves the same splice a single
    engine does, before AND after the ingest."""
    cfg, params, corpus = setup
    sc = ServeConfig(max_len=128, n_slots=2, method="none", tp=4,
                     kv_page_size=16, retrieval=_rcfg(corpus))
    router = Router.build(cfg, params, sc, n_replicas=2,
                          key=jax.random.PRNGKey(0))
    n0 = router.service.n_docs
    extra = build_corpus(16, retrieval_vocab=128, doc_max=8,
                         gen_vocab=cfg.vocab_size, embed_dim=16, seed=9)
    router.ingest(extra)
    assert router.service.n_docs == n0 + 16
    for r in router.replicas:    # every replica sees the grown corpus
        assert r.engine.retrieval.service.n_docs == n0 + 16

    # single engine on the SAME shared service == fleet, post-ingest
    ref_sc = ServeConfig(
        max_len=128, n_slots=2, method="none", tp=4, kv_page_size=16,
        retrieval=RetrievalConfig(
            mode="sync", kind="rag", corpus=corpus, k=2, trigger="flare",
            tau=1.1, min_interval=3, max_retrievals=1, query_window=6,
            service=router.service))
    ref_eng = Engine(cfg, params, ref_sc, key=jax.random.PRNGKey(0))
    assert ref_eng.retrieval.service is router.service
    prompts = _prompts(cfg, (16, 24), seed=5)
    refs = {}
    for i, p in enumerate(prompts):
        ref_eng.submit(Request(i, p, 8, retrieval=True))
        ref_eng.drain()
        refs[i] = list(ref_eng.done.pop(i).tokens)
    hs = [router.submit(Request(i, p, 8, retrieval=True))
          for i, p in enumerate(prompts)]
    router.drain()
    for h in hs:
        assert h.done
        np.testing.assert_array_equal(np.asarray(h.tokens),
                                      np.asarray(refs[h.rid]))
    assert any(r.engine.retrieval.events for r in router.replicas)
    rep = router.report()
    assert rep["shared_corpus"]["n_docs"] == n0 + 16


def test_request_surface_validation():
    """The typed admission surface rejects malformed requests loudly."""
    tok = np.arange(4, dtype=np.int32)
    with pytest.raises(ValueError):
        Request(0, tok, 0)                       # max_new < 1
    with pytest.raises(ValueError):
        Request(0, np.zeros((2, 2), np.int32), 3)   # not 1-D
    with pytest.raises(ValueError):
        Request(0, tok, 3, method_overrides={"bogus": 1})
    r = Request(1, tok, 3, method_overrides={"chunked": True})
    assert r.override("chunked") and r.override("method") is None
    assert len(r) == 4
    with pytest.raises(ValueError):
        r.tokens[0] = 5                          # frozen token buffer
