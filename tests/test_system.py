"""End-to-end behaviour: train-loss-decreases, serving with the memory
pipeline + dynamic fallback, continuous batching under the scheduler."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.data import TokenStream
from repro.models import init_params
from repro.serving import Engine, ServeConfig, Scheduler
from repro.train import OptConfig, TrainConfig, Trainer


@pytest.fixture(scope="module")
def trained():
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    tc = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=5, total_steps=100),
                     tp=4)
    tr = Trainer(cfg, tc, params)
    ds = TokenStream(cfg.vocab_size, 64, 4, seed=0)
    losses = [tr.train_step({k: jnp.asarray(v) for k, v in b.items()})["loss"]
              for _, b in zip(range(25), ds)]
    return cfg, tr.params, losses


def test_training_loss_decreases(trained):
    _, _, losses = trained
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_grad_accumulation_matches_plain(trained):
    """accum=2 over a split batch == accum=1 over the full batch."""
    cfg, params, _ = trained
    from repro.train import make_train_step, init_opt_state
    ds = TokenStream(cfg.vocab_size, 32, 4, seed=3)
    b = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    tc1 = TrainConfig(tp=4, accum=1)
    tc2 = TrainConfig(tp=4, accum=2)
    s1 = make_train_step(cfg, tc1)
    s2 = make_train_step(cfg, tc2)
    copy = lambda: jax.tree.map(jnp.copy, params)  # steps donate their args
    p1, o1, st1 = s1(copy(), init_opt_state(params), b)
    b2 = {k: v.reshape((2, 2) + v.shape[1:]) for k, v in b.items()}
    p2, o2, st2 = s2(copy(), init_opt_state(params), b2)
    assert float(st1["loss"]) == pytest.approx(float(st2["loss"]), rel=1e-3)
    ga, gb = jax.tree.leaves(p1), jax.tree.leaves(p2)
    for a, bb in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(bb, np.float32),
                                   rtol=2e-2, atol=2e-4)


@pytest.mark.parametrize("method", ["none", "dsa", "seer", "lserve"])
def test_serving_generates(trained, method):
    cfg, params, _ = trained
    eng = Engine(cfg, params, ServeConfig(max_len=96, n_slots=2,
                                          method=method, tp=4, page=8),
                 key=jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 24), 0,
                                 cfg.vocab_size)
    out = eng.generate(prompts, max_new=6)
    assert out.shape == (2, 6)
    assert out.min() >= 0 and out.max() < cfg.padded_vocab


def test_dynamic_fallback_consistency(trained):
    """Below min_context the engine's cond must take the dense branch —
    outputs equal the method='none' engine exactly."""
    cfg, params, _ = trained
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0,
                                 cfg.vocab_size)
    mem = cfg.memory.replace(min_context=10_000)  # force dense branch
    e_sparse = Engine(cfg, params, ServeConfig(max_len=64, method="dsa",
                                               tp=4, page=8),
                      key=jax.random.PRNGKey(0), mem=mem)
    e_dense = Engine(cfg, params, ServeConfig(max_len=64, method="none", tp=4))
    o1 = e_sparse.generate(prompts, 4)
    o2 = e_dense.generate(prompts, 4)
    np.testing.assert_array_equal(o1, o2)


def test_continuous_batching_scheduler(trained):
    cfg, params, _ = trained
    eng = Engine(cfg, params, ServeConfig(max_len=64, n_slots=3, method="none",
                                          tp=4))
    sch = Scheduler(eng)
    rng = np.random.default_rng(0)
    rids = [sch.submit(rng.integers(0, cfg.vocab_size, size=10), max_new=4)
            for _ in range(7)]
    done = sch.run()
    assert sorted(done) == sorted(rids)
    assert all(len(r.tokens) == 4 for r in done.values())
    assert sch.throughput_tokens_per_s() > 0
