"""Paged continuous batching: pooled decode with per-slot lengths must emit
token streams identical to per-request ``Engine.generate`` (dense and
sparse), pages must not leak across admit/release cycles, chunked prefill
must match one-shot prefill, and the scheduler must drain mixed workloads
over the paged pool. All admission goes through the request-level API
(``Engine.submit(Request)`` + ``poll``)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import init_params
from repro.serving import Engine, PagedKVPool, Request, ServeConfig, \
    Scheduler


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    return cfg, params


def _drain(eng, n_steps):
    got = {}
    for _ in range(n_steps):
        for rid, _slot, tok in eng.poll():
            got.setdefault(rid, []).append(tok)
    return got


@pytest.mark.parametrize("method", ["none", "dsa"])
def test_pooled_decode_matches_per_request_generate(setup, method):
    """Mixed-length slots (incl. a ragged non-pow2 prompt) admitted through
    the bucketed batched prefill decode EXACTLY like per-request generate."""
    cfg, params = setup
    sc = ServeConfig(max_len=96, n_slots=3, method=method, tp=4, page=8,
                     kv_page_size=16)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    ref = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (16, 32, 9)]
    max_new = 6
    refs = [ref.generate(jnp.asarray(p)[None], max_new)[0] for p in prompts]
    hs = [eng.submit(Request(i, p, max_new)) for i, p in enumerate(prompts)]
    got = _drain(eng, max_new + 1)
    assert all(h.done for h in hs)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(np.asarray(got[i][:max_new]), refs[i])
        np.testing.assert_array_equal(np.asarray(hs[i].tokens), refs[i])
    assert eng.pool.pages_in_use() == 0  # all pages released at completion


def test_staggered_admission_and_page_reuse(setup):
    """Admission mid-decode reuses released pages; token streams stay exact
    even though slots sit at heterogeneous positions. Requests beyond the
    slot count queue at submit and admit as slots free."""
    cfg, params = setup
    sc = ServeConfig(max_len=96, n_slots=2, method="none", tp=4,
                     kv_page_size=16, pool_pages=2 * (96 // 16) + 1)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    ref = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (16, 24, 40, 8)]
    refs = [ref.generate(jnp.asarray(p)[None], 5)[0] for p in prompts]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, 5))
    got = {}
    for rid, _slot, tok in eng.poll():
        got.setdefault(rid, []).append(tok)
    # only two slots: requests 2 and 3 stay queued (clean rejection,
    # re-queued at the front in FCFS order)
    assert eng.queue_depth() == 2
    assert sorted(got) == [0, 1]
    for _ in range(15):
        for rid, _slot, tok in eng.poll():
            got.setdefault(rid, []).append(tok)
    for i in range(4):
        np.testing.assert_array_equal(np.asarray(got[i][:5]), refs[i])
    assert eng.pool.pages_in_use() == 0


def test_pages_do_not_leak_across_admit_release_cycles(setup):
    """Repeated admit/decode/complete cycles return every page: the free
    list ends at full capacity with no duplicate page ids."""
    cfg, params = setup
    sc = ServeConfig(max_len=64, n_slots=2, method="none", tp=4,
                     kv_page_size=16)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    rid = 0
    for cycle in range(3):
        for n in (10, 20):
            eng.submit(Request(
                rid, rng.integers(0, cfg.vocab_size, size=n), 3))
            rid += 1
        eng.poll()   # admits both queued requests, then one decode step
        assert eng.queue_depth() == 0
        in_use = eng.pool.pages_in_use()
        assert in_use == eng.pool.pages_needed(10 + 3) + \
            eng.pool.pages_needed(20 + 3)
        _drain(eng, 3)
        assert eng.pool.pages_in_use() == 0
        free = eng.pool.free
        assert len(free) == len(set(free)) == eng.pool.total_pages - 1
        assert 0 not in free  # the zero page is never handed out


def test_pool_oversubscription_blocks_then_admits(setup):
    """With an arena smaller than full backing, admission waits for pages
    (not slots) and proceeds once a release frees them."""
    cfg, params = setup
    # 3 pages of 16: one request of 33..48 tokens takes all three
    sc = ServeConfig(max_len=64, n_slots=2, method="none", tp=4,
                     kv_page_size=16, pool_pages=4)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    h0 = eng.submit(Request(0, rng.integers(0, cfg.vocab_size, size=40), 4))
    h1 = eng.submit(Request(1, rng.integers(0, cfg.vocab_size, size=10), 4))
    eng.poll()
    assert eng.pool.n_free() == 0
    # a free slot exists but no pages: request 1 must stay queued
    assert eng.slots.free_slots()
    assert eng.queue_depth() == 1 and not h1.tokens
    done = eng.drain()
    assert sorted(done) == [0, 1]
    assert h0.done and h1.done
    assert eng.pool.n_free() == 3


def test_chunked_prefill_matches_one_shot(setup):
    """A long prompt streamed in chunks (interleaved with another slot's
    decode) produces the same tokens as one-shot prefill + generate; the
    admission path picks chunked mode from ``chunk_threshold`` (and a
    ``method_overrides`` pin can force it)."""
    cfg, params = setup
    sc = ServeConfig(max_len=128, n_slots=2, method="none", tp=4,
                     kv_page_size=16, prefill_chunk=16, chunk_threshold=24)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    ref = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    long_prompt = rng.integers(0, cfg.vocab_size, size=50).astype(np.int32)
    short = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    r_long = ref.generate(jnp.asarray(long_prompt)[None], 5)[0]
    r_short = ref.generate(jnp.asarray(short)[None], 5)[0]
    eng.submit(Request(0, long_prompt, 5,
                       method_overrides={"chunked": True}))
    eng.submit(Request(1, short, 5))
    got = _drain(eng, 12)
    np.testing.assert_array_equal(np.asarray(got[0][:5]), r_long)
    np.testing.assert_array_equal(np.asarray(got[1][:5]), r_short)
    assert eng.pool.pages_in_use() == 0


def test_scheduler_paged_mixed_lengths(setup):
    """End-to-end: bucketed + chunked admission under the scheduler, with an
    oversubscribed arena, drains everything."""
    cfg, params = setup
    sc = ServeConfig(max_len=128, n_slots=3, method="none", tp=4,
                     kv_page_size=16, prefill_chunk=16, chunk_threshold=32,
                     pool_pages=3 * (128 // 16) + 1)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    sch = Scheduler(eng, prefill_token_budget=64)
    rng = np.random.default_rng(5)
    lens = [10, 40, 16, 33, 8, 50, 12]
    rids = [sch.submit(rng.integers(0, cfg.vocab_size, size=n), max_new=4)
            for n in lens]
    done = sch.run()
    assert sorted(done) == sorted(rids)
    assert all(len(r.tokens) == 4 for r in done.values())
    assert sch.throughput_tokens_per_s() > 0
    assert eng.pool.pages_in_use() == 0


def test_legacy_watermark_pool_still_serves(setup):
    """The paged=False baseline (dense pool, shared watermark) remains a
    working scheduler target — it is the benchmark comparison point."""
    cfg, params = setup
    eng = Engine(cfg, params, ServeConfig(max_len=64, n_slots=3,
                                          method="none", tp=4, paged=False))
    sch = Scheduler(eng)
    rng = np.random.default_rng(6)
    rids = [sch.submit(rng.integers(0, cfg.vocab_size, size=10), max_new=4)
            for _ in range(5)]
    done = sch.run()
    assert sorted(done) == sorted(rids)
    assert all(len(r.tokens) == 4 for r in done.values())
