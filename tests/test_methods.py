"""Application-level methods: RAG (single + two-stage), MemAgent, MaC, TTT."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.methods import rag, memagent, mac, ttt
from repro.data import build_corpus, sample_queries
from repro.models import init_params, prefill, decode_step


@pytest.fixture(scope="module")
def corpus():
    return build_corpus(512, retrieval_vocab=256, doc_max=32, gen_vocab=512,
                        embed_dim=16, seed=0)


def test_bm25_retrieves_source_doc(corpus):
    """Queries sampled from a doc's own terms should rank that doc high."""
    B, T = 4, 8
    q = sample_queries(corpus, B, T, seed=1)
    scores, ids = rag.bm25_retrieve(corpus, q, k=16, fused=True)
    assert ids.shape == (B, 16)
    assert bool((scores[:, 0] > 0).all())
    s2, ids2 = rag.bm25_retrieve(corpus, q, k=16, fused=False)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_two_stage_rerank(corpus):
    B, T = 2, 8
    q = sample_queries(corpus, B, T, seed=2)
    q_emb = jnp.ones((B, 16), jnp.float32) / 4.0
    _, cand = rag.hybrid_retrieve(corpus, q, q_emb, n_first=32)
    assert cand.shape == (B, 32)

    def score_fn(query_tokens, docs):  # toy cross-encoder: token overlap
        return (docs.astype(jnp.float32).mean(-1)
                - jnp.abs(docs.astype(jnp.float32).mean(-1)
                          - query_tokens.astype(jnp.float32).mean(-1)[:, None]))

    top, ids = rag.rerank(score_fn, corpus, q, cand, k=4)
    assert ids.shape == (B, 4)
    # reranked ids are a subset of first-stage candidates
    for b in range(B):
        assert set(np.asarray(ids[b]).tolist()) <= set(np.asarray(cand[b]).tolist())


def test_append_to_query(corpus):
    q = jnp.ones((2, 10), jnp.int32)
    ids = jnp.zeros((2, 3), jnp.int32)
    out = rag.append_to_query(corpus, q, ids, max_len=64)
    assert out.shape[1] <= 64
    assert bool((out[:, -10:] == 1).all())  # query survives at the end


def test_dynamic_triggers():
    logits = jnp.asarray([[10.0, 0.0, 0.0], [0.1, 0.1, 0.1]])
    f = rag.flare_trigger(logits, tau=0.6)
    assert not bool(f[0]) and bool(f[1])  # confident vs uncertain
    d = rag.dragin_trigger(logits, jnp.asarray([1.0, 1.0]), tau=0.9)
    assert bool(d[1]) and not bool(d[0])


def test_memagent_loop():
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    ma = memagent.MemAgentConfig(segment_len=16, mem_len=4, max_answer=4)
    pf = jax.jit(lambda p, t, ml: prefill(p, cfg, t, max_len=int(ml), tp=4),
                 static_argnums=(2,))
    df = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c, tp=4))
    doc = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    qn = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab_size)
    ans = memagent.run_memagent(params, cfg, doc, qn, ma,
                                prefill_fn=pf, decode_fn=df)
    assert ans.shape == (2, 4)
    assert bool((ans >= 0).all())


def test_mac_segment_pipeline():
    cfg = get_arch("llama3.2-1b").smoke()
    mc = mac.MacConfig(segment_len=16, memory_slots=8, retrieve_k=2)
    mp = mac.mac_init(jax.random.PRNGKey(0), cfg)
    bank = mac.bank_init(cfg, mc, batch=2)
    seg = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    ctx, bank = mac.segment_step(mp, bank, seg, mc)
    assert ctx.shape == (2, 16 + mc.retrieve_k, cfg.d_model)
    new_mem = mac.prepare_memory(mp, seg)
    bank = mac.push(bank, new_mem)
    assert int(bank["count"]) == 1
    # retrieval after a push returns finite embeddings
    ctx2, _ = mac.segment_step(mp, bank, seg, mc)
    assert bool(jnp.isfinite(ctx2).all())
    # FIFO: memory_slots+2 pushes keep count clamped
    for _ in range(mc.memory_slots + 2):
        bank = mac.push(bank, new_mem)
    assert int(bank["count"]) == mc.memory_slots


def test_mac_build_pipeline_matches_segment_step():
    """The 4-stage descriptor threads the relevancy scores into retrieve
    (no recompute) and must produce exactly segment_step's context."""
    cfg = get_arch("llama3.2-1b").smoke()
    mc = mac.MacConfig(segment_len=16, memory_slots=8, retrieve_k=2)
    mp = mac.mac_init(jax.random.PRNGKey(0), cfg)
    bank = mac.bank_init(cfg, mc, batch=2)
    seg = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    for _ in range(3):
        bank = mac.push(bank, mac.prepare_memory(mp, seg))
        seg = seg + 0.1
    ref, _ = mac.segment_step(mp, bank, seg, mc)
    pipe = mac.build_pipeline(mp, mc)
    out = pipe.run((seg, bank), seg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # stage contract: relevancy's scores are what retrieve consumes
    I = pipe.prepare((seg, bank))
    scores = pipe.relevancy(I, seg)
    got = pipe.retrieve((seg, bank), scores)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(mac.retrieve(bank["bank"], scores, bank["count"], mc)))


def test_ttt_reduces_reconstruction_loss():
    """The fast-weight update must reduce reconstruction loss within a
    sequence (that's the definition of test-time training)."""
    cfg = get_arch("xlstm-125m").smoke()
    p = ttt.ttt_init(jax.random.PRNGKey(0), cfg, fast_dim=32)
    B, S = 2, 128
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    W0 = ttt.fast_state_init(cfg, B, fast_dim=32)
    _, W1 = ttt.ttt_forward(p, x, W0, chunk=32)
    k = jax.nn.silu(x.astype(jnp.float32) @ p["wk"])
    v = x.astype(jnp.float32) @ p["wv"]
    loss0 = float(jnp.mean((jnp.einsum("bsf,bfg->bsg", k, W0) - v) ** 2))
    loss1 = float(jnp.mean((jnp.einsum("bsf,bfg->bsg", k, W1) - v) ** 2))
    assert loss1 < loss0
