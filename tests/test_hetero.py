"""Heterogeneous offload subsystem (src/repro/hetero).

The load-bearing property: the OVERLAPPED schedule (lookahead selection on
the offload device, double-buffered against decode) must emit token streams
BIT-IDENTICAL to the SYNCHRONOUS schedule of the same two-phase dataflow —
async dispatch and the device transfer queue must not change results. On a
single-device environment both "devices" resolve to CPU 0 and the property
still holds; CI additionally runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` for a real split.

Also covered: stale-lookahead validity (selections never point outside the
live region they were computed from), the placement policy's stage->device
plan, the dynamic single-device fallback window, and preservation of the
paged pool's zero-page invariant under offload.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_arch
from repro.core.methods import offload_stages
from repro.hetero import dynamic_mode, pick_devices, plan_stage_placement
from repro.models import init_params
from repro.serving import Engine, OffloadConfig, Request, ServeConfig, \
    Scheduler


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("llama3.2-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    return cfg, params


def _drain(eng, n_steps):
    got = {}
    for _ in range(n_steps):
        for rid, _slot, tok in eng.poll():
            got.setdefault(rid, []).append(tok)
    return got


def _free_pages_zero(pool) -> bool:
    """Every page on the free list (and the reserved page 0) must be zero."""
    idx = np.asarray([0] + pool.free, np.int32)
    k = np.asarray(pool.device["k_pages"][:, idx], np.float32)
    v = np.asarray(pool.device["v_pages"][:, idx], np.float32)
    return not k.any() and not v.any()


@pytest.mark.parametrize("method", ["dsa", "seer", "lserve"])
def test_overlap_bitmatches_sync(setup, method):
    """Overlapped offload decode == synchronous two-phase decode, token for
    token, for every sparse method; pages are returned clean afterwards."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (16, 9)]
    streams = {}
    for mode in ("sync", "overlap"):
        sc = ServeConfig(max_len=64, n_slots=2, method=method, tp=4, page=8,
                         kv_page_size=16,
                         offload_cfg=OffloadConfig(
                             mode=mode, validate=(mode == "overlap")))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, 5))
        streams[mode] = _drain(eng, 6)
        assert eng.pool.pages_in_use() == 0
        assert _free_pages_zero(eng.pool)   # zero-page invariant survives
    for rid in range(len(prompts)):
        np.testing.assert_array_equal(streams["sync"][rid][:5],
                                      streams["overlap"][rid][:5])


def test_overlap_bitmatches_sync_under_scheduler(setup):
    """Mixed workload (bucketed + chunked admission, staggered completion,
    selection invalidation on every membership change) stays bit-identical
    between the two schedules end to end."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (10, 40, 16, 33)]
    streams = {}
    for mode in ("sync", "overlap"):
        sc = ServeConfig(max_len=128, n_slots=2, method="dsa", tp=4, page=8,
                         kv_page_size=16, prefill_chunk=16,
                         chunk_threshold=32,
                         offload_cfg=OffloadConfig(mode=mode))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
        sch = Scheduler(eng, prefill_token_budget=32)
        rids = [sch.submit(p, max_new=4) for p in prompts]
        done = sch.run()
        assert sorted(done) == sorted(rids)
        streams[mode] = {r: done[r].tokens for r in done}
        assert eng.pool.pages_in_use() == 0
        assert _free_pages_zero(eng.pool)
    assert streams["sync"] == streams["overlap"]


def test_seer_threshold_selection_offloads(setup):
    """SeerAttention's threshold retrieval mode runs through the offload
    select path and stays schedule-invariant."""
    cfg, params = setup
    mem = cfg.memory.replace(method="seer", selection="threshold")
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, size=20).astype(np.int32)
    streams = {}
    for mode in ("sync", "overlap"):
        sc = ServeConfig(max_len=64, n_slots=2, method="seer", tp=4,
                         kv_page_size=16,
                         offload_cfg=OffloadConfig(mode=mode))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0), mem=mem)
        eng.submit(Request(0, prompt, 5))
        streams[mode] = _drain(eng, 6)
    np.testing.assert_array_equal(streams["sync"][0], streams["overlap"][0])


def test_stale_lookahead_validity(setup):
    """validate=True replays every consumed selection synchronously (bitwise
    equality) inside the executor; on top, the pending lookahead buffer must
    only hold indices inside the live region it was computed from."""
    cfg, params = setup
    sc = ServeConfig(max_len=96, n_slots=2, method="dsa", tp=4, page=8,
                     kv_page_size=16,
                     offload_cfg=OffloadConfig(mode="overlap",
                                               validate=True))
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, size=24), 6))
    got = {}
    for step in range(8):
        for rid, _s, tok in eng.poll():
            got.setdefault(rid, []).append(tok)
        if step == 2:   # staggered admission forces a lookahead restart
            eng.submit(Request(
                1, rng.integers(0, cfg.vocab_size, size=12), 4))
        hx = eng.hetero
        if hx.sel_buf is not None:
            _, _, lengths = hx._sel_inputs
            sel = np.asarray(jax.block_until_ready(hx.sel_buf))
            lens = np.asarray(lengths)
            ok = (sel == -1) | ((sel >= 0) &
                               (sel * hx.sel.page < lens[None, :, None]))
            assert ok.all(), "lookahead selected pages beyond the live region"
    assert len(got[0]) == 6 and len(got[1]) == 4
    assert eng.hetero.profiler.offload_steps > 0


def test_placement_policy_stage_split():
    """Paper §4/§5.2: memory-bound index stages offload, the KV-touching
    apply and the compute-dense rest stay on the main device."""
    cfg = get_arch("llama3.2-1b")
    plan = plan_stage_placement(cfg, cfg.memory, context=65536)
    assert plan.stages["relevancy"] == "offload"
    assert plan.stages["retrieve"] == "offload"
    assert plan.stages["apply"] == "main"       # reads raw KV pages
    assert plan.stages["rest"] == "main"        # compute-dense remainder
    assert plan.memory_bound["retrieve"]
    # methods that must not offload anything (paper §4 for ttt)
    assert offload_stages("ttt") == ()
    assert offload_stages("memagent") == ()
    assert offload_stages("none") == ()
    assert "relevancy" in offload_stages("rag")


def test_dynamic_fallback_window():
    """Host-side fallback mirror: outside [min_context, fallback_context]
    the executor must run single-device (matching the traced cond)."""
    mem = get_arch("llama3.2-1b").memory
    assert dynamic_mode(mem.min_context - 1, mem) == "local"
    assert dynamic_mode(mem.min_context, mem) == "offload"
    assert dynamic_mode(mem.fallback_context, mem) == "offload"
    assert dynamic_mode(mem.fallback_context + 1, mem) == "local"
    assert dynamic_mode(65536, mem.replace(method="ttt")) == "local"
    main, off = pick_devices()
    assert main is not None and off is not None


def test_dynamic_fallback_serves_below_min_context(setup):
    """With min_context above the workload, every step takes the local
    (dense, single-device) path — and still bit-matches across schedules."""
    cfg, params = setup
    mem = cfg.memory.replace(method="dsa", min_context=1 << 16)
    streams = {}
    for mode in ("sync", "overlap"):
        sc = ServeConfig(max_len=64, n_slots=2, method="dsa", tp=4, page=8,
                         kv_page_size=16,
                         offload_cfg=OffloadConfig(mode=mode))
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(0), mem=mem)
        rng = np.random.default_rng(9)
        eng.submit(Request(0, rng.integers(0, cfg.vocab_size, size=16), 4))
        streams[mode] = _drain(eng, 5)
        assert eng.hetero.profiler.local_steps > 0
        assert eng.hetero.profiler.offload_steps == 0
    np.testing.assert_array_equal(streams["sync"][0], streams["overlap"][0])
