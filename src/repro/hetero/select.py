"""Offload-side memory index + selection (the emulated FPGA bitstream).

For each sparse method the offload device keeps a compact, incrementally
maintained SUMMARY of the key cache in logical (slot, page) space — the
paper's "compressed memory resides on the accelerator" — and answers
lookahead queries with top-k page indices:

  dsa    : per-micro-page SUM of lightning-indexer key projections
           (mean recovered at score time; score = w-weighted ReLU inner
           product, identical math to the fused relevancy kernel);
  seer   : per-block SUM of gate-projected keys (mean-pooled block keys),
           optional threshold selection on softmax-normalized scores;
  lserve : per-logical-page channel-wise MIN/MAX of raw keys, max-reduced
           over physical-page groups.

Summaries are updated from the SAME per-layer keys the main device writes
into the KV pool (one token per decode step, spans at prefill), so summary
state is a pure function of the token stream — which is what makes the
overlapped executor bit-match its synchronous schedule. Zero-initialized
summaries mirror the paged pool's zero-page invariant: a page the pool
considers zero scores exactly like an all-zero key page.

All functions are pure jnp so the executor can jit them once and pin them
to the offload device via committed inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MemoryConfig

NEG_INF = -1e30
BIG = 3e30  # finite min/max sentinel (inf would poison 0 * inf -> nan)


@dataclasses.dataclass(frozen=True)
class OffloadSelect:
    """Per-method offload-side implementation bundle."""

    method: str
    page: int                 # selection granularity (tokens per page)
    n_sel: int                # width of the returned index vector
    n_pages: int              # logical pages per slot (max_len // page)
    summary_init: Callable    # () -> summary pytree
    reset: Callable           # (summary, slot_ids) -> summary
    ingest: Callable          # (summary, sp, k_new, pos, live) -> summary
    ingest_span: Callable     # (summary, sp, k_span, slots, start, n_valid)
    select: Callable          # (sp, summary, q_layers, lengths) -> pidx


def _qf_layers(q_layers: jnp.ndarray, n_in: int) -> jnp.ndarray:
    """[L, B, Hp, hd] -> [L, B, n_in]: flatten heads, strip TP dead-head
    padding (matches the inline ``qf[:, :n_in]`` slice)."""
    L, B = q_layers.shape[:2]
    return q_layers.reshape(L, B, -1)[:, :, :n_in]


def _mask_topk(scores: jnp.ndarray, lengths: jnp.ndarray, page: int,
               k: int):
    """scores [L, B, P]; mask pages beyond the live region, then top-k.
    Returns (vals, idx) with idx = -1 where nothing live was selectable."""
    P = scores.shape[-1]
    page_live = (jnp.arange(P)[None, None, :] * page
                 < lengths[None, :, None])
    scores = jnp.where(page_live, scores, NEG_INF)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, jnp.where(vals > NEG_INF / 2, idx, -1)


# ---------------------------------------------------------------------------
# shared per-page SUM summary (dsa indexer projections / seer gate
# projections differ only in page size and projection-weight key)
# ---------------------------------------------------------------------------


def _sum_summary(key: str, weight: str, page: int, L: int, n_slots: int,
                 P: int, di: int):
    """(summary_init, reset, ingest, ingest_span) for a summary that holds,
    per logical page, the SUM of ``k @ sp[weight]`` over its live tokens."""

    def summary_init():
        return {key: jnp.zeros((L, n_slots, P, di), jnp.float32)}

    def reset(s, slot_ids):
        return {key: s[key].at[:, slot_ids].set(0.0)}

    def _contrib(sp, k):  # [L, ..., KV, hd] -> [L, ..., di]
        kf = k.reshape(*k.shape[:-2], -1)
        return jnp.einsum("l...f,lfd->l...d", kf,
                          sp[weight]).astype(jnp.float32)

    def ingest(s, sp, k_new, pos, live):
        B = pos.shape[0]
        c = _contrib(sp, k_new) * live.astype(jnp.float32)[None, :, None]
        pages = jnp.clip(pos // page, 0, P - 1)
        return {key: s[key].at[:, jnp.arange(B), pages].add(c)}

    def ingest_span(s, sp, k_span, slot_ids, start, n_valid):
        S = k_span.shape[2]
        valid = jnp.arange(S)[None, :] < n_valid[:, None]        # [Bg, S]
        c = _contrib(sp, k_span) * valid[None, :, :, None]
        pages = jnp.clip((start[:, None] + jnp.arange(S)[None, :]) // page,
                         0, P - 1)                               # [Bg, S]
        return {key: s[key].at[:, slot_ids[:, None], pages].add(c)}

    return summary_init, reset, ingest, ingest_span


# ---------------------------------------------------------------------------
# dsa — lightning-indexer micro-page sums
# ---------------------------------------------------------------------------


def _dsa(cfg: ArchConfig, mem: MemoryConfig, page: int, n_slots: int,
         max_len: int) -> OffloadSelect:
    P = max_len // page
    n_sel = min(max(mem.top_k // page, 1), P)
    L = cfg.n_layers
    di = mem.index_dim
    n_in = cfg.n_heads * cfg.hd
    summary_init, reset, ingest, ingest_span = _sum_summary(
        "kidx_sum", "wk_idx", page, L, n_slots, P, di)

    def select(sp, s, q_layers, lengths):
        qf = _qf_layers(q_layers, n_in)
        q_idx = jnp.einsum("lbf,lfe->lbe", qf, sp["wq_idx"])
        q_idx = q_idx.reshape(*q_idx.shape[:2], -1, di).astype(jnp.float32)
        w = jax.nn.softmax(
            jnp.einsum("lbf,lfh->lbh", qf.astype(jnp.float32), sp["w_wgt"]),
            axis=-1)
        kp = s["kidx_sum"] * (1.0 / page)         # page means, [L, B, P, di]
        dots = jnp.einsum("lbhd,lbpd->lbhp", q_idx, kp)
        scores = jnp.einsum("lbh,lbhp->lbp", w, jax.nn.relu(dots))
        _, idx = _mask_topk(scores, lengths, page, n_sel)
        return idx.astype(jnp.int32)

    return OffloadSelect("dsa", page, n_sel, P, summary_init, reset, ingest,
                         ingest_span, select)


# ---------------------------------------------------------------------------
# seer — gate-projected block sums (+ threshold selection)
# ---------------------------------------------------------------------------


def _seer(cfg: ArchConfig, mem: MemoryConfig, n_slots: int,
          max_len: int) -> OffloadSelect:
    bs = mem.block_size
    P = max_len // bs
    n_sel = min(max(mem.token_budget // bs, 1), P)
    L = cfg.n_layers
    di = mem.index_dim
    n_in = cfg.n_heads * cfg.hd
    summary_init, reset, ingest, ingest_span = _sum_summary(
        "kgate_sum", "wk_gate", bs, L, n_slots, P, di)

    def select(sp, s, q_layers, lengths):
        qf = _qf_layers(q_layers, n_in)
        q_gate = jnp.einsum("lbf,lfd->lbd", qf,
                            sp["wq_gate"]).astype(jnp.float32)
        k_blk = s["kgate_sum"] * (1.0 / bs)                 # block means
        scores = jax.nn.relu(
            jnp.einsum("lbd,lbpd->lbp", q_gate, k_blk))
        vals, idx = _mask_topk(scores, lengths, bs, n_sel)
        if mem.selection == "threshold":
            probs = jax.nn.softmax(vals, axis=-1)
            idx = jnp.where(probs >= mem.threshold, idx, -1)
        return idx.astype(jnp.int32)

    return OffloadSelect("seer", bs, n_sel, P, summary_init, reset, ingest,
                         ingest_span, select)


# ---------------------------------------------------------------------------
# lserve — per-page channel min/max bounds, physical-page grouping
# ---------------------------------------------------------------------------


def _lserve(cfg: ArchConfig, mem: MemoryConfig, n_slots: int,
            max_len: int) -> OffloadSelect:
    ps = mem.block_size
    ppp = mem.pages_per_physical
    P = max_len // ps
    Pphys = max(P // ppp, 1)
    n_phys = min(max(mem.token_budget // (ps * ppp), 1), Pphys)
    n_sel = n_phys * ppp
    L = cfg.n_layers
    kv, hd = cfg.n_kv_heads, cfg.hd

    def summary_init():
        return {"pmin": jnp.full((L, n_slots, P, kv, hd), BIG, jnp.float32),
                "pmax": jnp.full((L, n_slots, P, kv, hd), -BIG, jnp.float32)}

    def reset(s, slot_ids):
        return {"pmin": s["pmin"].at[:, slot_ids].set(BIG),
                "pmax": s["pmax"].at[:, slot_ids].set(-BIG)}

    def ingest(s, sp, k_new, pos, live):
        B = pos.shape[0]
        kf = k_new.astype(jnp.float32)
        m = live[None, :, None, None]
        lo = jnp.where(m, kf, BIG)
        hi = jnp.where(m, kf, -BIG)
        pages = jnp.clip(pos // ps, 0, P - 1)
        b = jnp.arange(B)
        return {"pmin": s["pmin"].at[:, b, pages].min(lo),
                "pmax": s["pmax"].at[:, b, pages].max(hi)}

    def ingest_span(s, sp, k_span, slot_ids, start, n_valid):
        S = k_span.shape[2]
        kf = k_span.astype(jnp.float32)
        valid = (jnp.arange(S)[None, :]
                 < n_valid[:, None])[None, :, :, None, None]
        lo = jnp.where(valid, kf, BIG)
        hi = jnp.where(valid, kf, -BIG)
        pages = jnp.clip((start[:, None] + jnp.arange(S)[None, :]) // ps,
                         0, P - 1)
        return {"pmin": s["pmin"].at[:, slot_ids[:, None], pages].min(lo),
                "pmax": s["pmax"].at[:, slot_ids[:, None], pages].max(hi)}

    def select(sp, s, q_layers, lengths):
        # reduce the kv-head axis for the bound (same as the inline path)
        pmin = s["pmin"].max(axis=3)                       # [L, B, P, hd]
        pmax = s["pmax"].max(axis=3)
        qf = q_layers.astype(jnp.float32)                  # [L, B, Hp, hd]
        pm = jnp.maximum(qf[:, :, :, None, :] * pmin[:, :, None],
                         qf[:, :, :, None, :] * pmax[:, :, None])
        sc = pm.sum(-1).mean(axis=2)                       # [L, B, P]
        page_live = (jnp.arange(P)[None, None, :] * ps
                     < lengths[None, :, None])
        sc = jnp.where(page_live, sc, NEG_INF)
        phys = sc.reshape(*sc.shape[:2], Pphys, ppp).max(-1)
        vals, pidx = jax.lax.top_k(phys, n_phys)           # [L, B, n_phys]
        logical = (pidx[..., None] * ppp + jnp.arange(ppp)
                   ).reshape(*pidx.shape[:2], -1)          # [L, B, n_sel]
        live = ((logical * ps < lengths[None, :, None])
                & jnp.repeat(vals > NEG_INF / 2, ppp, axis=-1))
        return jnp.where(live, logical, -1).astype(jnp.int32)

    return OffloadSelect("lserve", ps, n_sel, P, summary_init, reset, ingest,
                         ingest_span, select)


# ---------------------------------------------------------------------------


def make_offload_select(method: str, cfg: ArchConfig, mem: MemoryConfig, *,
                        dsa_page: int, n_slots: int, max_len: int,
                        corpus=None, mac=None, rag_k: int = 4,
                        capacity: int = 0) -> OffloadSelect:
    """One bundle per OFFLOAD_STAGES declarer. The sparse-attention family
    (dsa/seer/lserve) keeps KV-page summaries; the document-memory family
    (rag/mac, built in ``repro.retrieval.select``) keeps the corpus index /
    per-slot memory banks — same protocol, different state. ``corpus`` /
    ``mac`` configure the retrieval-family builders and are ignored by the
    sparse ones."""
    builders: Dict[str, Callable] = {
        "dsa": lambda: _dsa(cfg, mem, dsa_page, n_slots, max_len),
        "seer": lambda: _seer(cfg, mem, n_slots, max_len),
        "lserve": lambda: _lserve(cfg, mem, n_slots, max_len),
    }
    if method in ("rag", "mac"):
        from repro.retrieval.select import make_retrieval_select
        return make_retrieval_select(method, cfg, n_slots=n_slots,
                                     corpus=corpus, mac=mac, k=rag_k,
                                     capacity=capacity)
    if method not in builders:
        raise KeyError(f"method {method!r} has no offload-side selection: "
                       f"{sorted(builders) + ['rag', 'mac']}")
    return builders[method]()
