"""Offload-side memory index + selection (the emulated FPGA bitstream).

For each sparse method the offload device keeps a compact, incrementally
maintained SUMMARY of the key cache in logical (slot, page) space — the
paper's "compressed memory resides on the accelerator" — and answers
lookahead queries with top-k page indices:

  dsa    : per-micro-page SUM of lightning-indexer key projections
           (mean recovered at score time; score = w-weighted ReLU inner
           product, identical math to the fused relevancy kernel);
  seer   : per-block SUM of gate-projected keys (mean-pooled block keys),
           optional threshold selection on softmax-normalized scores;
  lserve : per-logical-page channel-wise MIN/MAX of raw keys, max-reduced
           over physical-page groups.

Summaries are updated from the SAME per-layer keys the main device writes
into the KV pool (one token per decode step, spans at prefill), so summary
state is a pure function of the token stream — which is what makes the
overlapped executor bit-match its synchronous schedule. Zero-initialized
summaries mirror the paged pool's zero-page invariant: a page the pool
considers zero scores exactly like an all-zero key page.

SHARDING (paper §5.2 / Fig. 6a at scale): every bundle is built over a
WINDOW ``(tok_lo, n_tok)`` of the logical token space — the full window by
default, one contiguous KV-sequence shard per offload device under the
sharded executor. Ingest masks tokens outside the window (so each shard's
index covers exactly its pages), ``select_partial`` returns the shard's
top candidates as ``(vals, idx)`` pairs in GLOBAL page coordinates — the
index-only exchange unit, 8 bytes per candidate — and ``finalize`` merges
candidate lists into the final page selection on the compute side.
``select = finalize ∘ select_partial``: the single-device path is the
one-shard special case of the same math, and because per-page scores are
independent of the window extent and ``jax.lax.top_k`` breaks ties by
ascending index on shard-ordered candidates, the merged selection is
bit-identical to the unsharded one.

All functions are pure jnp so the executor can jit them once and pin them
to the offload device via committed inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MemoryConfig

NEG_INF = -1e30
BIG = 3e30  # finite min/max sentinel (inf would poison 0 * inf -> nan)


@dataclasses.dataclass(frozen=True)
class OffloadSelect:
    """Per-method offload-side implementation bundle (one per window)."""

    method: str
    page: int                 # selection granularity (tokens per page)
    n_sel: int                # width of the FINAL merged index vector
    n_pages: int              # logical pages in THIS bundle's window
    summary_init: Callable    # () -> summary pytree
    reset: Callable           # (summary, slot_ids) -> summary
    ingest: Callable          # (summary, sp, k_new, pos, live) -> summary
    ingest_span: Callable     # (summary, sp, k_span, slots, start, n_valid)
    select: Callable          # (sp, summary, q_layers, lengths) -> pidx
    # --- sharded protocol ---
    select_partial: Optional[Callable] = None
    #   (sp, summary, q_layers, lengths) -> (vals [L,B,n_part],
    #   idx [L,B,n_part] in GLOBAL page/physical-page coordinates)
    finalize: Optional[Callable] = None
    #   (vals [L,B,K], idx [L,B,K], lengths [B]) -> pidx [L,B,n_sel]
    n_part: int = 0           # candidate width of select_partial
    tok_lo: int = 0           # global token offset of the window
    n_tok: int = 0            # tokens covered by the window


def _qf_layers(q_layers: jnp.ndarray, n_in: int) -> jnp.ndarray:
    """[L, B, Hp, hd] -> [L, B, n_in]: flatten heads, strip TP dead-head
    padding (matches the inline ``qf[:, :n_in]`` slice)."""
    L, B = q_layers.shape[:2]
    return q_layers.reshape(L, B, -1)[:, :, :n_in]


def _win_mask(P: int, page: int, tok_lo: int, lengths: jnp.ndarray):
    """[L?, B, P] page-liveness mask for a window starting at ``tok_lo``:
    page p covers global tokens [tok_lo + p*page, ...), live iff its first
    token is inside the slot's live region."""
    return ((tok_lo + jnp.arange(P)[None, None, :] * page)
            < lengths[None, :, None])


def merge_shard_topk(vals: jnp.ndarray, idx: jnp.ndarray, k: int):
    """Top-k over (shard-ordered) candidate lists. Candidates within a
    shard are index-ascending among ties (lax.top_k is stable) and shards
    concatenate in ascending-window order, so tie-breaking here matches a
    global top-k exactly — the merged selection is bit-identical to the
    unsharded one."""
    k = min(k, vals.shape[-1])
    top_v, pos = jax.lax.top_k(vals, k)
    top_i = jnp.take_along_axis(idx, pos, axis=-1)
    return top_v, top_i


# ---------------------------------------------------------------------------
# shared per-page SUM summary (dsa indexer projections / seer gate
# projections differ only in page size and projection-weight key)
# ---------------------------------------------------------------------------


def _sum_summary(key: str, weight: str, page: int, L: int, n_slots: int,
                 P: int, di: int, tok_lo: int):
    """(summary_init, reset, ingest, ingest_span) for a summary that holds,
    per logical page of the window [tok_lo, tok_lo + P*page), the SUM of
    ``k @ sp[weight]`` over its live tokens. Tokens outside the window are
    masked out (their contribution lands on a clipped page as exact zero),
    so a sharded bundle ingests the same stream as the full one and simply
    ignores what it does not own."""
    tok_hi = tok_lo + P * page

    def summary_init():
        return {key: jnp.zeros((L, n_slots, P, di), jnp.float32)}

    def reset(s, slot_ids):
        return {key: s[key].at[:, slot_ids].set(0.0)}

    def _contrib(sp, k):  # [L, ..., KV, hd] -> [L, ..., di]
        kf = k.reshape(*k.shape[:-2], -1)
        return jnp.einsum("l...f,lfd->l...d", kf,
                          sp[weight]).astype(jnp.float32)

    def ingest(s, sp, k_new, pos, live):
        B = pos.shape[0]
        own = live & (pos >= tok_lo) & (pos < tok_hi)
        c = _contrib(sp, k_new) * own.astype(jnp.float32)[None, :, None]
        pages = jnp.clip((pos - tok_lo) // page, 0, P - 1)
        return {key: s[key].at[:, jnp.arange(B), pages].add(c)}

    def ingest_span(s, sp, k_span, slot_ids, start, n_valid):
        S = k_span.shape[2]
        gpos = start[:, None] + jnp.arange(S)[None, :]           # [Bg, S]
        valid = ((jnp.arange(S)[None, :] < n_valid[:, None])
                 & (gpos >= tok_lo) & (gpos < tok_hi))
        c = _contrib(sp, k_span) * valid[None, :, :, None]
        pages = jnp.clip((gpos - tok_lo) // page, 0, P - 1)      # [Bg, S]
        return {key: s[key].at[:, slot_ids[:, None], pages].add(c)}

    return summary_init, reset, ingest, ingest_span


# ---------------------------------------------------------------------------
# dsa — lightning-indexer micro-page sums
# ---------------------------------------------------------------------------


def _dsa(cfg: ArchConfig, mem: MemoryConfig, page: int, n_slots: int,
         max_len: int, window: Optional[Tuple[int, int]] = None
         ) -> OffloadSelect:
    tok_lo, n_tok = window or (0, max_len)
    P = n_tok // page                         # pages in this window
    n_sel = min(max(mem.top_k // page, 1), max_len // page)
    n_part = min(n_sel, P)
    L = cfg.n_layers
    di = mem.index_dim
    n_in = cfg.n_heads * cfg.hd
    summary_init, reset, ingest, ingest_span = _sum_summary(
        "kidx_sum", "wk_idx", page, L, n_slots, P, di, tok_lo)

    def select_partial(sp, s, q_layers, lengths):
        qf = _qf_layers(q_layers, n_in)
        q_idx = jnp.einsum("lbf,lfe->lbe", qf, sp["wq_idx"])
        q_idx = q_idx.reshape(*q_idx.shape[:2], -1, di).astype(jnp.float32)
        w = jax.nn.softmax(
            jnp.einsum("lbf,lfh->lbh", qf.astype(jnp.float32), sp["w_wgt"]),
            axis=-1)
        kp = s["kidx_sum"] * (1.0 / page)         # page means, [L, B, P, di]
        dots = jnp.einsum("lbhd,lbpd->lbhp", q_idx, kp)
        scores = jnp.einsum("lbh,lbhp->lbp", w, jax.nn.relu(dots))
        scores = jnp.where(_win_mask(P, page, tok_lo, lengths), scores,
                           NEG_INF)
        vals, idx = jax.lax.top_k(scores, n_part)
        return vals, (idx + tok_lo // page).astype(jnp.int32)

    def finalize(vals, idx, lengths):
        top_v, top_i = merge_shard_topk(vals, idx, n_sel)
        return jnp.where(top_v > NEG_INF / 2, top_i, -1).astype(jnp.int32)

    def select(sp, s, q_layers, lengths):
        vals, idx = select_partial(sp, s, q_layers, lengths)
        return finalize(vals, idx, lengths)

    return OffloadSelect("dsa", page, n_sel, P, summary_init, reset, ingest,
                         ingest_span, select, select_partial, finalize,
                         n_part, tok_lo, n_tok)


# ---------------------------------------------------------------------------
# seer — gate-projected block sums (+ threshold selection)
# ---------------------------------------------------------------------------


def _seer(cfg: ArchConfig, mem: MemoryConfig, n_slots: int,
          max_len: int, window: Optional[Tuple[int, int]] = None
          ) -> OffloadSelect:
    bs = mem.block_size
    tok_lo, n_tok = window or (0, max_len)
    P = n_tok // bs
    n_sel = min(max(mem.token_budget // bs, 1), max_len // bs)
    n_part = min(n_sel, P)
    L = cfg.n_layers
    di = mem.index_dim
    n_in = cfg.n_heads * cfg.hd
    summary_init, reset, ingest, ingest_span = _sum_summary(
        "kgate_sum", "wk_gate", bs, L, n_slots, P, di, tok_lo)

    def select_partial(sp, s, q_layers, lengths):
        qf = _qf_layers(q_layers, n_in)
        q_gate = jnp.einsum("lbf,lfd->lbd", qf,
                            sp["wq_gate"]).astype(jnp.float32)
        k_blk = s["kgate_sum"] * (1.0 / bs)                 # block means
        scores = jax.nn.relu(
            jnp.einsum("lbd,lbpd->lbp", q_gate, k_blk))
        scores = jnp.where(_win_mask(P, bs, tok_lo, lengths), scores,
                           NEG_INF)
        vals, idx = jax.lax.top_k(scores, n_part)
        return vals, (idx + tok_lo // bs).astype(jnp.int32)

    def finalize(vals, idx, lengths):
        top_v, top_i = merge_shard_topk(vals, idx, n_sel)
        out = jnp.where(top_v > NEG_INF / 2, top_i, -1)
        if mem.selection == "threshold":
            probs = jax.nn.softmax(top_v, axis=-1)
            out = jnp.where(probs >= mem.threshold, out, -1)
        return out.astype(jnp.int32)

    def select(sp, s, q_layers, lengths):
        vals, idx = select_partial(sp, s, q_layers, lengths)
        return finalize(vals, idx, lengths)

    return OffloadSelect("seer", bs, n_sel, P, summary_init, reset, ingest,
                         ingest_span, select, select_partial, finalize,
                         n_part, tok_lo, n_tok)


# ---------------------------------------------------------------------------
# lserve — per-page channel min/max bounds, physical-page grouping
# ---------------------------------------------------------------------------


def _lserve(cfg: ArchConfig, mem: MemoryConfig, n_slots: int,
            max_len: int, window: Optional[Tuple[int, int]] = None
            ) -> OffloadSelect:
    ps = mem.block_size
    ppp = mem.pages_per_physical
    tok_lo, n_tok = window or (0, max_len)
    P = n_tok // ps
    Pphys = max(P // ppp, 1)
    Pphys_full = max(max_len // ps // ppp, 1)
    n_phys = min(max(mem.token_budget // (ps * ppp), 1), Pphys_full)
    n_sel = n_phys * ppp
    n_part = min(n_phys, Pphys)               # candidates are PHYSICAL pages
    assert P % ppp == 0 and tok_lo % (ps * ppp) == 0, \
        "lserve shard windows must align to physical-page groups"
    L = cfg.n_layers
    kv, hd = cfg.n_kv_heads, cfg.hd
    tok_hi = tok_lo + n_tok

    def summary_init():
        return {"pmin": jnp.full((L, n_slots, P, kv, hd), BIG, jnp.float32),
                "pmax": jnp.full((L, n_slots, P, kv, hd), -BIG, jnp.float32)}

    def reset(s, slot_ids):
        return {"pmin": s["pmin"].at[:, slot_ids].set(BIG),
                "pmax": s["pmax"].at[:, slot_ids].set(-BIG)}

    def ingest(s, sp, k_new, pos, live):
        B = pos.shape[0]
        kf = k_new.astype(jnp.float32)
        own = live & (pos >= tok_lo) & (pos < tok_hi)
        m = own[None, :, None, None]
        lo = jnp.where(m, kf, BIG)
        hi = jnp.where(m, kf, -BIG)
        pages = jnp.clip((pos - tok_lo) // ps, 0, P - 1)
        b = jnp.arange(B)
        return {"pmin": s["pmin"].at[:, b, pages].min(lo),
                "pmax": s["pmax"].at[:, b, pages].max(hi)}

    def ingest_span(s, sp, k_span, slot_ids, start, n_valid):
        S = k_span.shape[2]
        kf = k_span.astype(jnp.float32)
        gpos = start[:, None] + jnp.arange(S)[None, :]           # [Bg, S]
        valid = ((jnp.arange(S)[None, :] < n_valid[:, None])
                 & (gpos >= tok_lo)
                 & (gpos < tok_hi))[None, :, :, None, None]
        lo = jnp.where(valid, kf, BIG)
        hi = jnp.where(valid, kf, -BIG)
        pages = jnp.clip((gpos - tok_lo) // ps, 0, P - 1)
        return {"pmin": s["pmin"].at[:, slot_ids[:, None], pages].min(lo),
                "pmax": s["pmax"].at[:, slot_ids[:, None], pages].max(hi)}

    def select_partial(sp, s, q_layers, lengths):
        # reduce the kv-head axis for the bound (same as the inline path)
        pmin = s["pmin"].max(axis=3)                       # [L, B, P, hd]
        pmax = s["pmax"].max(axis=3)
        qf = q_layers.astype(jnp.float32)                  # [L, B, Hp, hd]
        pm = jnp.maximum(qf[:, :, :, None, :] * pmin[:, :, None],
                         qf[:, :, :, None, :] * pmax[:, :, None])
        sc = pm.sum(-1).mean(axis=2)                       # [L, B, P]
        sc = jnp.where(_win_mask(P, ps, tok_lo, lengths), sc, NEG_INF)
        phys = sc.reshape(*sc.shape[:2], Pphys, ppp).max(-1)
        vals, pidx = jax.lax.top_k(phys, n_part)           # [L, B, n_part]
        return vals, (pidx + tok_lo // (ps * ppp)).astype(jnp.int32)

    def finalize(vals, idx, lengths):
        top_v, top_i = merge_shard_topk(vals, idx, n_phys)
        logical = (top_i[..., None] * ppp + jnp.arange(ppp)
                   ).reshape(*top_i.shape[:2], -1)          # [L, B, n_sel]
        live = ((logical * ps < lengths[None, :, None])
                & jnp.repeat(top_v > NEG_INF / 2, ppp, axis=-1))
        return jnp.where(live, logical, -1).astype(jnp.int32)

    def select(sp, s, q_layers, lengths):
        vals, idx = select_partial(sp, s, q_layers, lengths)
        return finalize(vals, idx, lengths)

    return OffloadSelect("lserve", ps, n_sel, P, summary_init, reset, ingest,
                         ingest_span, select, select_partial, finalize,
                         n_part, tok_lo, n_tok)


# ---------------------------------------------------------------------------


def make_offload_select(method: str, cfg: ArchConfig, mem: MemoryConfig, *,
                        dsa_page: int, n_slots: int, max_len: int,
                        corpus=None, mac=None, rag_k: int = 4,
                        capacity: int = 0,
                        window: Optional[Tuple[int, int]] = None
                        ) -> OffloadSelect:
    """One bundle per OFFLOAD_STAGES declarer. The sparse-attention family
    (dsa/seer/lserve) keeps KV-page summaries; the document-memory family
    (rag/mac, built in ``repro.retrieval.select``) keeps the corpus index /
    per-slot memory banks — same protocol, different state. ``corpus`` /
    ``mac`` configure the retrieval-family builders and are ignored by the
    sparse ones. ``window=(tok_lo, n_tok)`` builds the bundle over one
    contiguous KV-sequence shard of the logical token space (sparse family
    only; the document-memory state has no sequence axis to shard)."""
    builders: Dict[str, Callable] = {
        "dsa": lambda: _dsa(cfg, mem, dsa_page, n_slots, max_len, window),
        "seer": lambda: _seer(cfg, mem, n_slots, max_len, window),
        "lserve": lambda: _lserve(cfg, mem, n_slots, max_len, window),
    }
    if method in ("rag", "mac"):
        assert window is None, "document-memory bundles do not shard"
        from repro.retrieval.select import make_retrieval_select
        return make_retrieval_select(method, cfg, n_slots=n_slots,
                                     corpus=corpus, mac=mac, k=rag_k,
                                     capacity=capacity)
    if method not in builders:
        raise KeyError(f"method {method!r} has no offload-side selection: "
                       f"{sorted(builders) + ['rag', 'mac']}")
    return builders[method]()
