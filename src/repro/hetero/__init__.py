"""Heterogeneous offload subsystem (paper §4-§5).

Emulates the paper's GPU<->FPGA split on two JAX devices: the sparse,
memory-bound memory-processing stages (prepare / relevancy / retrieve) run
on a secondary device and exchange only compact indices with the primary
device that keeps the compute-dense decode (apply + rest). Run the test /
CI configuration with ``XLA_FLAGS=--xla_force_host_platform_device_count=2``
to get two real host devices; with one device the subsystem still runs (the
transfer queue degenerates to no-ops) so single-device environments stay
supported.
"""
from repro.hetero.executor import HeteroExecutor
from repro.hetero.policy import (OffloadPlan, dynamic_mode, pick_devices,
                                 pick_devices_replicas, pick_devices_sharded,
                                 plan_stage_placement, resolve_cli_offload,
                                 resolve_cli_retrieval)
from repro.hetero.profiler import HeteroProfiler
from repro.hetero.sharded import ShardedHeteroExecutor
from repro.hetero.transfer import TransferLedger

__all__ = [
    "HeteroExecutor", "HeteroProfiler", "OffloadPlan",
    "ShardedHeteroExecutor", "TransferLedger", "dynamic_mode",
    "pick_devices", "pick_devices_replicas", "pick_devices_sharded",
    "plan_stage_placement",
    "resolve_cli_offload", "resolve_cli_retrieval",
]
