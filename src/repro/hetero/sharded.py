"""Sharded hetero offload: one offload device per KV-sequence shard (§5.2,
Fig. 6a at scale — HGCA/HeteGen-style memory-side parallelism).

``ShardedHeteroExecutor`` generalizes the two-device ``HeteroExecutor`` to a
``(main, offload_0..offload_{n-1})`` topology. The logical token space
[0, max_len) is cut into ``n_shards`` contiguous windows; each offload
device keeps the incremental page-summary index of ITS window only (dsa
indexer sums / seer gate sums / lserve min-max bounds, built by
``hetero.select`` with a static shard window) and answers the lookahead
query with its local top-k candidates.

What crosses which link, per decode step:

  main -> shard_s   this step's per-layer queries + new keys (the shard
                    masks what it does not own — index maintenance);
  shard_s -> main   (vals, idx) candidate pairs in GLOBAL page coordinates:
                    8 bytes per candidate, ``n_part <= n_sel`` candidates —
                    the index-only exchange, O(k * shards) total, never a
                    raw score vector and never a KV page;
  main              candidate merge (top-k over shard-ordered lists) +
                    the apply phase over the paged pool.

Because per-page summary scores are independent of the window extent and
top-k tie-breaking on shard-ordered candidates matches a global top-k, the
merged selection is BIT-IDENTICAL to the single-offload-device executor's —
``offload_shards=2`` serves the same tokens as ``offload_shards=1`` in both
scheduling modes (tests/test_hetero_sharded.py). Each shard gets its own
``TransferLedger`` so the report shows per-link traffic and the O(k*shards)
exchange win.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MemoryConfig
from repro.hetero import policy as hpolicy
from repro.hetero.executor import HeteroExecutor, _is_ready
from repro.hetero.select import make_offload_select
from repro.hetero.transfer import TransferLedger


class ShardedHeteroExecutor(HeteroExecutor):
    def __init__(self, cfg: ArchConfig, mem: MemoryConfig, sc,
                 sparse_params, *, mode: str = "overlap",
                 validate: bool = False, n_shards: int = 2, devices=None,
                 main_mesh=None):
        assert n_shards >= 1, n_shards
        assert sc.max_len % n_shards == 0, (sc.max_len, n_shards)
        self.n_shards = n_shards
        if devices is None:
            main, offs = hpolicy.pick_devices_sharded(n_shards)
        else:
            main, offs = devices
            offs = tuple(offs)
            assert len(offs) == n_shards, (len(offs), n_shards)
        self.off_devs = offs
        super().__init__(cfg, mem, sc, sparse_params, mode=mode,
                         validate=validate, devices=(main, offs[0]),
                         main_mesh=main_mesh)
        local = sc.max_len // n_shards
        assert local % self.sel.page == 0, \
            f"shard window {local} must align to the selection page " \
            f"({self.sel.page})"

    # ------------------------------------------------------------------
    # offload-resident state: one summary shard per device
    # ------------------------------------------------------------------

    def _init_offload_state(self, sparse_params) -> None:
        cfg, sc = self.cfg, self.sc
        n = self.n_shards
        local = sc.max_len // n
        self.shards = [
            make_offload_select(sc.method, cfg, self.mem, dsa_page=sc.page,
                                n_slots=sc.n_slots, max_len=sc.max_len,
                                window=(s * local, local))
            for s in range(n)
        ]
        self.ledgers = [TransferLedger() for _ in range(n)]
        self.sp_offs = [jax.device_put(sparse_params, d)
                        for d in self.off_devs]
        self.summaries = [jax.device_put(sh.summary_init(), d)
                          for sh, d in zip(self.shards, self.off_devs)]
        from repro.models import layers as L
        hp = cfg.padded_heads(sc.tp)
        q0 = jnp.zeros((cfg.n_layers, sc.n_slots, hp, cfg.hd),
                       L.dtype_of(cfg))
        self.q_bufs = [jax.device_put(q0, d) for d in self.off_devs]
        self._partial_jits = [jax.jit(sh.select_partial)
                              for sh in self.shards]
        self._ingest_jits = [jax.jit(sh.ingest) for sh in self.shards]
        self._finalize_jit = jax.jit(self.sel.finalize)

    # ------------------------------------------------------------------
    # selection-state primitives
    # ------------------------------------------------------------------

    def _launch_select(self, lengths_np: np.ndarray):
        """Queue the fused relevancy+top-k on EVERY shard device (async
        dispatch runs them concurrently). Handle = per-shard (vals, idx)
        candidate pairs in global page coordinates."""
        lengths = jnp.asarray(lengths_np, jnp.int32)
        handles, pins = [], []
        for s in range(self.n_shards):
            inputs = (self.summaries[s], self.q_bufs[s], lengths)
            handles.append(self._partial_jits[s](self.sp_offs[s], *inputs))
            pins.append(inputs)
        return handles, pins

    def _select_from_pinned(self, inputs):
        return [self._partial_jits[s](self.sp_offs[s], *inputs[s])
                for s in range(self.n_shards)]

    def _raw_lengths(self, inputs):
        return inputs[0][2]

    def _merge(self, ups, lengths):
        """Merge shard candidate lists (already on the main device) into
        the final pidx. Shard order = ascending window order, so top-k
        tie-breaking matches the unsharded selection exactly."""
        vals = jnp.concatenate([u[0] for u in ups], axis=-1)
        idx = jnp.concatenate([u[1] for u in ups], axis=-1)
        return self._finalize_jit(vals, idx, lengths)

    def _to_apply(self, handle, inputs=None):
        """Index-only up exchange: ship each shard's (vals, idx) pairs —
        8 bytes per candidate — and merge on the apply side (single main
        device, or replicated over the main mesh so the merged pidx feeds
        the sequence-parallel apply without a device conflict). READY
        handles (fused-window exit lookahead) are already merged there."""
        if _is_ready(handle):
            return handle[1]
        ups = [self.ledgers[s].ship_up(handle[s], self._apply_target)
               for s in range(self.n_shards)]
        pins = inputs if inputs is not None else self._sel_inputs
        return self._merge(ups, self._pinned_lengths(pins))

    def _handle_to_pidx(self, handle, inputs):
        ups = [jax.device_put(h, self._apply_target) for h in handle]
        return self._merge(ups, self._pinned_lengths(inputs))

    def _pin_state(self):
        return list(self.summaries), list(self.q_bufs)

    def _ingest_step(self, pinned, q_t, k_t, lengths, live):
        sums, qs = pinned
        for s in range(self.n_shards):
            q_off = self.ledgers[s].ship_down(q_t, self.off_devs[s])
            k_off = self.ledgers[s].ship_down(k_t, self.off_devs[s])
            self.summaries[s] = self._ingest_jits[s](
                sums[s], self.sp_offs[s], k_off, lengths, live)
            self.q_bufs[s] = self._blend_q(qs[s], q_off, None, live)
        return self.summaries

    def _tick(self) -> None:
        for led in self.ledgers:
            led.tick()

    # ------------------------------------------------------------------
    # fused multi-step windows
    # ------------------------------------------------------------------

    def _fused_state_up(self):
        """Concatenate the shard summaries along the PAGE axis (axis 2 —
        windows are contiguous and ascending, so the concat IS the
        full-window summary: windowed ingest writes only the pages a shard
        owns) and ship the result to the apply target. The in-scan select
        over it is bit-identical to the merged per-shard selection
        (merge_shard_topk's tie-breaking contract). q_bufs are identical
        across shards (same blend inputs), so shard 0's suffices."""
        sums = [self.ledgers[s].ship_down(self.summaries[s],
                                          self._apply_target, bulk=True)
                for s in range(self.n_shards)]
        summary = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=2), *sums)
        qbuf = self.ledgers[0].ship_down(self.q_bufs[0], self._apply_target,
                                         bulk=True)
        return summary, qbuf

    def _fused_state_down(self, summary, qbuf):
        """Scatter the post-window summary back: each shard takes its page
        window (slice of axis 2); every shard's q_buf takes the full
        blended buffer."""
        for s in range(self.n_shards):
            sh = self.shards[s]
            lo = sh.tok_lo // sh.page
            sl = jax.tree_util.tree_map(
                lambda x, lo=lo, n=sh.n_pages: x[:, :, lo: lo + n], summary)
            self.summaries[s] = self.ledgers[s].ship_down(
                sl, self.off_devs[s], bulk=True)
            self.q_bufs[s] = self.ledgers[s].ship_down(
                qbuf, self.off_devs[s], bulk=True)

    # ------------------------------------------------------------------
    # admission / prefill hooks
    # ------------------------------------------------------------------

    def _reset_slots(self, slot_ids: List[int]) -> None:
        sid = jnp.asarray(slot_ids, jnp.int32)
        for s in range(self.n_shards):
            self.summaries[s] = self.shards[s].reset(self.summaries[s], sid)

    def _clear_q(self, slot_ids: List[int]) -> None:
        sid = jnp.asarray(slot_ids, jnp.int32)
        for s in range(self.n_shards):
            self.q_bufs[s] = self.q_bufs[s].at[:, sid].set(0.0)

    def _seed_span(self, slot_ids, k_masked, start_np, n_valid_np, q_last,
                   *, keep_q: np.ndarray = None) -> None:
        """Route the span to every shard; each shard's windowed ingest
        keeps exactly the pages it owns (splices and chunked extends land
        on the owning shard's index)."""
        sid = jnp.asarray(slot_ids, jnp.int32)
        start = jnp.asarray(start_np, jnp.int32)
        n_valid = jnp.asarray(n_valid_np, jnp.int32)
        for s in range(self.n_shards):
            k_off = self.ledgers[s].ship_down(k_masked, self.off_devs[s],
                                              bulk=True)
            q_off = self.ledgers[s].ship_down(q_last, self.off_devs[s],
                                              bulk=True)
            Bg, S = k_off.shape[1], k_off.shape[2]
            key = (s, Bg, S)
            if key not in self._span_jits:
                self._span_jits[key] = jax.jit(self.shards[s].ingest_span)
            self.summaries[s] = self._span_jits[key](
                self.summaries[s], self.sp_offs[s], k_off, sid, start,
                n_valid)
            self.q_bufs[s] = self._blend_q(self.q_bufs[s], q_off, sid,
                                           keep_q)

    # ------------------------------------------------------------------

    def report(self) -> Dict:
        self.ledger = TransferLedger.combine(self.ledgers)
        d = super().report()
        d["devices"] = {
            "main": str(self.main_dev),
            "offload": [str(x) for x in self.off_devs],
            "distinct": any(x != self.main_dev for x in self.off_devs),
        }
        if self.main_mesh is not None:
            d["devices"]["main_mesh"] = [
                str(x) for x in self.main_mesh.devices.flat]
        d["shards"] = {
            "n_shards": self.n_shards,
            "window_tokens": self.sc.max_len // self.n_shards,
            "windows": [[sh.tok_lo, sh.tok_lo + sh.n_tok]
                        for sh in self.shards],
            "candidates_per_shard": self.shards[0].n_part,
            "per_shard_transfer": [led.as_dict() for led in self.ledgers],
            "distinct_offload_devices": len({str(x)
                                             for x in self.off_devs}),
        }
        return d
