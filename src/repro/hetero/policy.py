"""Placement policy for the heterogeneous offload subsystem.

Decides, per memory-pipeline stage, which device executes it (paper §4
Table 2 + §5.2). Two rules compose:

  1. KV ownership: a stage that reads the *raw* KV values (apply) is pinned
     to the device that owns the KV pool — shipping pages over the
     interconnect is exactly what the paper's index-only design avoids.
     This is encoded as per-method stage metadata
     (``core.methods.offload_stages``).
  2. Roofline: among the offloadable stages, only the memory-bound ones
     (bytes-limited under ``placement.StageCost``) actually move — a
     compute-dense stage is better served by the main device's FLOPs.

On top of the static plan sits the paper's DYNAMIC FALLBACK (§5.2 /
Appendix F): outside the ``[min_context, fallback_context]`` window the
whole step collapses to single-device dense execution; the executor then
launches no offload work at all. ``dynamic_mode`` is the host-side mirror
of the traced predicate ``placement.traced_use_sparse`` — the two MUST
agree or the engine would launch selections that the jitted cond ignores
(or vice versa).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.configs.base import ArchConfig, MemoryConfig
from repro.core import placement
from repro.core.methods import offload_stages

MAIN = "main"
OFFLOAD = "offload"


@dataclasses.dataclass(frozen=True)
class OffloadPlan:
    """Static stage->device plan plus the roofline evidence behind it."""

    method: str
    stages: Dict[str, str]            # stage -> MAIN | OFFLOAD
    intensity: Dict[str, float]       # stage -> FLOP/byte
    memory_bound: Dict[str, bool]

    def offloaded(self) -> Tuple[str, ...]:
        return tuple(s for s, d in self.stages.items() if d == OFFLOAD)


def plan_stage_placement(cfg: ArchConfig, mem: MemoryConfig, context: int,
                         batch: int = 1) -> OffloadPlan:
    """Static placement for the sparse-attention pipeline at ``context``."""
    costs = placement.sparse_attention_stage_costs(cfg, mem, context, batch)
    allowed = set(offload_stages(mem.method))
    stages, intensity, membound = {}, {}, {}
    for name, c in costs.items():
        intensity[name] = c.intensity
        membound[name] = c.memory_bound
        stages[name] = OFFLOAD if (name in allowed and c.memory_bound) \
            else MAIN
    return OffloadPlan(mem.method, stages, intensity, membound)


def dynamic_mode(context: int, mem: MemoryConfig) -> str:
    """'offload' | 'local' — host-side mirror of the traced fallback window.

    ``context`` is the max live context of the step INCLUDING the token
    being decoded (``lengths.max() + 1``), matching what the jitted cond in
    ``decode_step_paged_presel`` sees. Delegates to the single window owner
    in ``placement`` so the host schedule cannot drift from the traced
    branch.
    """
    return "offload" if placement.in_sparse_window(context, mem) else "local"


def resolve_cli_offload(value: str, method: str) -> str:
    """Map a launcher's ``--offload on|off|sync|overlap`` flag to a
    ``ServeConfig.offload`` mode (shared by launch/serve.py and the
    serving example). Raises ValueError when offload is requested without
    a sparse method."""
    mode = {"on": "overlap", "off": "off"}.get(value, value)
    if mode != "off" and method == "none":
        raise ValueError(
            "--offload needs a sparse --method (dsa | seer | lserve)")
    return mode


def resolve_cli_retrieval(value: str) -> str:
    """Map ``--retrieval off|on|inline|sync|overlap`` to a
    ``retrieval.RetrievalConfig.mode`` ('on' = the overlapped service;
    'off' returns '' meaning no retrieval service)."""
    mode = {"on": "overlap", "off": ""}.get(value, value)
    if mode and mode not in ("inline", "sync", "overlap"):
        raise ValueError(f"unknown retrieval mode {value!r}")
    return mode


def pick_devices():
    """(main, offload) JAX devices.

    With ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (or a real
    second accelerator) the offload device is distinct; otherwise both
    resolve to device 0 and the executor's transfers become no-ops — the
    subsystem stays functional on single-device environments.
    """
    import jax

    devs = jax.devices()
    return (devs[0], devs[1]) if len(devs) >= 2 else (devs[0], devs[0])


def pick_devices_mesh(n_main: int, n_shards: int = 1):
    """(main mesh devices, offload shard devices) for the fully sharded
    topology — N apply shards on a real MAIN mesh composing with M
    selection shards: mesh devices are [0, n), offload shards round-robin
    over the remainder (over everything when devices run short, as in
    ``pick_devices_sharded``).

    A JAX mesh cannot repeat a device, so when fewer than ``n_main``
    devices exist the mesh clamps to the largest DIVISOR of the request
    that fits — a divisor, not a plain min, so the engine's view alignment
    (granularity a multiple of the REQUESTED mesh) still divides the
    clamped shard count and ``S % (n_shards * page_size) == 0`` holds."""
    import jax

    devs = jax.devices()
    n = max(d for d in range(1, n_main + 1)
            if n_main % d == 0 and d <= len(devs))
    mains = tuple(devs[:n])
    pool = devs[n:] if len(devs) > n else devs
    return mains, tuple(pool[i % len(pool)] for i in range(n_shards))


def pick_devices_replicas(n_replicas: int):
    """Partition ``jax.devices()`` into ``n_replicas`` contiguous device
    GROUPS — one per fleet replica (serving.router). Each group's first
    device is the replica's main device (its Engine commits the params
    there); the rest serve that replica's offload/retrieval side, split by
    the per-engine policies above.

    With ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (or real
    accelerators), N >= n_replicas gives every replica ``N // n_replicas``
    devices and true parallel dispatch (JAX's async dispatch overlaps
    work across distinct devices from one host thread). Fewer devices
    round-robin — replicas share, transfers degenerate to no-ops, and the
    fleet stays functional on single-device environments like the other
    ``pick_devices*`` policies."""
    import jax

    assert n_replicas >= 1, n_replicas
    devs = jax.devices()
    if len(devs) >= n_replicas:
        per = len(devs) // n_replicas
        return [tuple(devs[i * per:(i + 1) * per])
                for i in range(n_replicas)]
    return [(devs[i % len(devs)],) for i in range(n_replicas)]


def pick_devices_sharded(n_shards: int):
    """(main, (offload_0, ..., offload_{n-1})) for the sharded executor:
    one offload device per KV-sequence shard.

    With ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (or real
    accelerators) shards land on devices 1..N-1 round-robin, so 1 + n_shards
    devices give every shard its own chip while smaller topologies still
    run (shards share offload devices; a single device degenerates every
    transfer to a no-op, as in the unsharded executor)."""
    import jax

    devs = jax.devices()
    pool = devs[1:] if len(devs) >= 2 else [devs[0]]
    return devs[0], tuple(pool[i % len(pool)] for i in range(n_shards))
