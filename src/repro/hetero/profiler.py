"""Per-stage timeline of the hetero offload executor (paper Fig. 3-5).

The synchronous two-phase schedule exposes the phase walls directly
(select / apply / exchange); the overlapped schedule by construction hides
the select phase under apply, so the profiler reports what is observable —
per-step wall time and the apply wall — plus the analytic decomposition.

Phase walls are attributed to the paper's four pipeline stages with the
roofline stage costs (``placement.sparse_attention_stage_costs``) as
weights: the select phase covers prepare+relevancy+retrieve, the apply
phase covers apply+rest — the same fused-attribution convention
``core.pipeline.StageProfiler`` uses for the fused kernel.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

from repro.configs.base import ArchConfig, MemoryConfig
from repro.core import placement

SELECT_STAGES = ("prepare", "relevancy", "retrieve")
APPLY_STAGES = ("apply", "rest")


class HeteroProfiler:
    def __init__(self, cfg: ArchConfig, mem: MemoryConfig, mode: str):
        self.cfg, self.mem, self.mode = cfg, mem, mode
        self.steps = 0
        self.tokens = 0
        self.step_s = 0.0
        self.select_s = 0.0       # sync mode only (hidden under overlap)
        self.apply_s = 0.0
        self.max_context = 1
        self.offload_steps = 0    # steps that actually ran the offload path
        self.local_steps = 0      # dynamic-fallback steps (single device)
        # lookahead pipeline health (per-slot invalidation, PR 4): a step
        # either reuses the pending overlapped selection (hit — possibly
        # patching the rows of slots whose membership changed) or cold-starts
        # a fresh one on the critical path.
        self.lookahead_hits = 0
        self.lookahead_cold = 0
        self.lookahead_patched = 0
        # fused multi-step windows (serving.fused): one host dispatch per
        # window instead of per step
        self.fused_windows = 0
        self.fused_steps = 0

    def record_step(self, n_live: int, context: int, step_s: float,
                    select_s: Optional[float] = None,
                    apply_s: Optional[float] = None,
                    offloaded: bool = True):
        self.steps += 1
        self.tokens += n_live
        self.step_s += step_s
        self.max_context = max(self.max_context, context)
        if select_s is not None:
            self.select_s += select_s
        if apply_s is not None:
            self.apply_s += apply_s
        if offloaded:
            self.offload_steps += 1
        else:
            self.local_steps += 1

    def record_fused(self, n_steps: int, n_tokens: int, context: int,
                     step_s: float, *, offload_steps: int,
                     local_steps: int):
        """One fused window of ``n_steps`` device steps behind a single
        host dispatch. Per-step offload/local attribution comes from the
        scan's emitted per-step fallback log."""
        self.steps += n_steps
        self.tokens += n_tokens
        self.step_s += step_s
        self.max_context = max(self.max_context,
                               context + max(n_steps - 1, 0))
        self.offload_steps += offload_steps
        self.local_steps += local_steps
        self.fused_windows += 1
        self.fused_steps += n_steps

    # -- Fig. 3-style decomposition ------------------------------------

    def _weights(self) -> Dict[str, float]:
        costs = placement.sparse_attention_stage_costs(
            self.cfg, self.mem, max(self.max_context, 1))
        return {s: c.seconds() for s, c in costs.items()}

    def stage_seconds(self) -> Dict[str, float]:
        """Measured phase walls apportioned to the four pipeline stages."""
        w = self._weights()
        out: Dict[str, float] = {}
        for group, total in ((SELECT_STAGES, self.select_s),
                             (APPLY_STAGES, self.apply_s)):
            gw = sum(w[s] for s in group) or 1.0
            for s in group:
                out[s] = total * w[s] / gw
        return out

    def fractions(self) -> Dict[str, float]:
        ss = self.stage_seconds()
        tot = sum(ss.values()) or 1.0
        return {s: v / tot for s, v in ss.items()}

    def memory_fraction(self) -> float:
        """Fraction of phase time in memory processing (everything but
        'rest') — the paper's headline metric."""
        ss = self.stage_seconds()
        tot = sum(ss.values())
        return (tot - ss.get("rest", 0.0)) / tot if tot else float("nan")

    # -- reporting ------------------------------------------------------

    def summary(self, ledger=None, **transfer_kw) -> Dict:
        d = {
            "mode": self.mode,
            "method": self.mem.method,
            "steps": self.steps,
            "tokens": self.tokens,
            "offload_steps": self.offload_steps,
            "local_fallback_steps": self.local_steps,
            "lookahead": {"hits": self.lookahead_hits,
                          "cold_starts": self.lookahead_cold,
                          "patched": self.lookahead_patched},
            "max_context": self.max_context,
            "fused": {"windows": self.fused_windows,
                      "steps": self.fused_steps,
                      "steps_per_dispatch": self.fused_steps
                      / max(self.fused_windows, 1)},
            "step_s_total": self.step_s,
            "us_per_step": 1e6 * self.step_s / max(self.steps, 1),
            "tokens_per_s": self.tokens / self.step_s if self.step_s else 0.0,
            "apply_s": self.apply_s,
        }
        if self.mode == "sync":
            d["select_s"] = self.select_s
            d["stage_fractions"] = self.fractions()
            d["memory_fraction"] = self.memory_fraction()
        else:
            d["select_hidden"] = True   # overlapped under apply
        if ledger is not None:
            d["transfer"] = ledger.as_dict(**transfer_kw)
        return d

    def to_json(self, path: Optional[str] = None, ledger=None,
                **transfer_kw) -> str:
        s = json.dumps(self.summary(ledger, **transfer_kw), indent=2,
                       sort_keys=True)
        if path:
            with open(path, "w") as f:
                f.write(s + "\n")
        return s
