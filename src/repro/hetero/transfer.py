"""Index-only device exchange + transfer-bytes accounting (paper §5.2).

The paper's PCIe-minimizing design ships three things and nothing else:

  down (main -> offload): the new per-layer keys of each decoded token
      (to keep the offload-resident memory index coherent) and the
      per-layer query activations (the relevancy input);
  bulk (main -> offload): the prompt's keys once at admission — the
      analogue of materializing the memory on the FPGA during prefill;
  up (offload -> main): top-k PAGE INDICES. Never KV pages.

``TransferLedger`` wraps ``jax.device_put`` so every exchange is counted,
and carries the analytic comparator (what shipping the retrieved KV pages
instead would cost) used by the benchmarks and the profiler JSON.
"""
from __future__ import annotations

from typing import Dict

import jax


def pytree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


class TransferLedger:
    def __init__(self):
        self.down_bytes = 0      # per-step index maintenance (q + new keys)
        self.bulk_bytes = 0      # admission-time prompt key shipping
        self.up_bytes = 0        # selection indices coming back
        self.span_bytes = 0      # retrieved doc-token / embedding payloads
        self.steps = 0

    @staticmethod
    def combine(ledgers) -> "TransferLedger":
        """Aggregate per-shard ledgers (sharded offload keeps one per
        offload device so the report can show each link's traffic): bytes
        sum across links, steps are the shared step clock (max)."""
        out = TransferLedger()
        for led in ledgers:
            out.down_bytes += led.down_bytes
            out.bulk_bytes += led.bulk_bytes
            out.up_bytes += led.up_bytes
            out.span_bytes += led.span_bytes
            out.steps = max(out.steps, led.steps)
        return out

    # -- counted device_put wrappers -----------------------------------

    def ship_down(self, tree, device, *, bulk: bool = False):
        n = pytree_bytes(tree)
        if bulk:
            self.bulk_bytes += n
        else:
            self.down_bytes += n
        return jax.device_put(tree, device)

    def ship_up(self, tree, device):
        """``device`` may be a single JAX device or a (replicated)
        NamedSharding when the apply side is a main mesh — replication
        physically moves ONE COPY PER MESH DEVICE, so the ledger counts
        every copy (same honesty rule as counting no-op same-device puts:
        bytes reflect the logical link, per destination)."""
        copies = getattr(getattr(device, "mesh", None), "size", 1)
        self.up_bytes += pytree_bytes(tree) * copies
        return jax.device_put(tree, device)

    def count_span(self, nbytes: int):
        """Retrieved-document payload returned by the retrieval engine
        (token spans / MaC embeddings) — the part of the ``up`` exchange
        that is data, not indices; tracked separately so the index-only
        comparison stays honest."""
        self.span_bytes += int(nbytes)

    def tick(self):
        self.steps += 1

    # -- analytic comparator -------------------------------------------

    @staticmethod
    def kv_pages_bytes_per_step(cfg, n_sel: int, page: int,
                                batch: int = 1) -> int:
        """Bytes/step a naive design would move: the retrieved K AND V
        pages for every layer (the thing the index-only exchange avoids)."""
        itemsize = 2  # bf16 cache
        return (cfg.n_layers * batch * n_sel * page *
                cfg.n_kv_heads * cfg.hd * itemsize * 2)

    def as_dict(self, cfg=None, n_sel: int = 0, page: int = 0,
                batch: int = 1) -> Dict:
        d = {
            "down_bytes": int(self.down_bytes),
            "bulk_prefill_bytes": int(self.bulk_bytes),
            "up_bytes": int(self.up_bytes),
            "span_bytes": int(self.span_bytes),
            "steps": int(self.steps),
        }
        if self.steps:
            d["down_bytes_per_step"] = self.down_bytes / self.steps
            d["up_bytes_per_step"] = self.up_bytes / self.steps
        if cfg is not None and n_sel and self.steps:
            kv = self.kv_pages_bytes_per_step(cfg, n_sel, page, batch)
            d["kv_pages_bytes_per_step_avoided"] = kv
            moved = (self.down_bytes + self.up_bytes) / self.steps
            d["exchange_reduction_x"] = kv / max(moved, 1.0)
        return d
