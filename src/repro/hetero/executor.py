"""Async offload executor: overlaps memory processing with decode (§5).

Two-phase decode with ONE STEP OF LOOKAHEAD, double-buffered across two
JAX devices:

  main device     apply_t (sparse attention over preselected pages + the
                  dense transformer remainder), then ships this step's
                  per-layer queries/keys to the offload device;
  offload device  runs select_{t+1} (prepare/relevancy/retrieve over its
                  incrementally maintained index summary) CONCURRENTLY
                  with apply_t, and ingests step t's keys afterwards.

The selection serving step t therefore saw the queries of step t-2 and the
keys through step t-2 — the stale-lookahead semantics the paper accepts in
exchange for hiding the memory-bound stages entirely (the freshly written
page is force-included at apply time, so recency is never lost).

Scheduling modes share ONE dataflow — every jitted function runs with the
same inputs in the same buffer order — and differ only in barriers:

  "overlap"  no host barriers; JAX async dispatch queues select_{t+1} on
             the offload device while the main device runs apply_t.
  "sync"     block_until_ready between phases: select, apply, ingest run
             serially. This is the honest single-timeline baseline the
             benchmarks compare against.

Because the dataflow is identical, the two modes are bit-identical
(tests/test_hetero.py proves it per method); ``validate=True`` additionally
re-executes every consumed selection synchronously from the pinned inputs
and asserts bitwise equality + stale-index validity, turning any buffer
misuse in the async schedule into an immediate failure.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MemoryConfig
from repro.hetero import policy as hpolicy
from repro.hetero.profiler import HeteroProfiler
from repro.hetero.select import make_offload_select
from repro.hetero.transfer import TransferLedger
from repro.models import model as M


class HeteroExecutor:
    def __init__(self, cfg: ArchConfig, mem: MemoryConfig, sc,
                 sparse_params, *, mode: str = "overlap",
                 validate: bool = False, devices=None):
        assert mode in ("sync", "overlap"), mode
        self.cfg, self.mem, self.sc, self.mode = cfg, mem, sc, mode
        self.validate = validate
        self.main_dev, self.off_dev = devices or hpolicy.pick_devices()
        self.sel = make_offload_select(sc.method, cfg, mem,
                                       dsa_page=sc.page,
                                       n_slots=sc.n_slots,
                                       max_len=sc.max_len)
        self.plan = hpolicy.plan_stage_placement(cfg, mem, sc.max_len)
        self.ledger = TransferLedger()
        self.profiler = HeteroProfiler(cfg, mem, mode)

        # offload-resident state: method params, index summary, stale query
        self.sp_off = jax.device_put(sparse_params, self.off_dev)
        self.summary = jax.device_put(self.sel.summary_init(), self.off_dev)
        from repro.models import layers as L
        hp = cfg.padded_heads(sc.tp)
        self.q_buf = jax.device_put(
            jnp.zeros((cfg.n_layers, sc.n_slots, hp, cfg.hd),
                      L.dtype_of(cfg)), self.off_dev)
        self.sel_buf = None            # selection for the NEXT decode step
        self._sel_inputs = None        # pinned (summary, q, lengths) of it
        self._neg_sel = jax.device_put(
            jnp.full((cfg.n_layers, sc.n_slots, self.sel.n_sel), -1,
                     jnp.int32), self.main_dev)

        self._select_jit = jax.jit(self.sel.select)
        self._ingest_jit = jax.jit(self.sel.ingest)
        self._span_jits: Dict[Tuple[int, int], callable] = {}
        self._apply_jits: Dict[int, callable] = {}

    @property
    def devices(self) -> Tuple:
        """(main, offload) — shared with co-resident services (the
        retrieval subsystem places its corpus/banks on the same offload
        device so one two-device environment hosts both)."""
        return self.main_dev, self.off_dev

    # ------------------------------------------------------------------
    # jit builders
    # ------------------------------------------------------------------

    def _apply_fn(self, n_pages_view: int):
        if n_pages_view not in self._apply_jits:
            cfg, mem, sc, ps = self.cfg, self.mem, self.sc, self.sel.page
            self._apply_jits[n_pages_view] = jax.jit(
                lambda p, tok, kp, vp, table, lengths, live, pidx:
                M.decode_step_paged_presel(
                    p, cfg, tok,
                    {"k_pages": kp, "v_pages": vp, "page_table": table,
                     "lengths": lengths},
                    live, pidx, mem, page_size=ps, tp=sc.tp),
                donate_argnums=(2, 3))
        return self._apply_jits[n_pages_view]

    def _span_fn(self, Bg: int, S: int):
        key = (Bg, S)
        if key not in self._span_jits:
            self._span_jits[key] = jax.jit(self.sel.ingest_span)
        return self._span_jits[key]

    def _launch_select(self, lengths_np: np.ndarray):
        """Queue a selection on the offload device from the CURRENT summary
        and stale-query buffers; pins the inputs for validation."""
        lengths = jnp.asarray(lengths_np, jnp.int32)
        inputs = (self.summary, self.q_buf, lengths)
        self._sel_inputs = inputs
        return self._select_jit(self.sp_off, *inputs)

    # ------------------------------------------------------------------
    # admission / prefill hooks (keep the offload index coherent)
    # ------------------------------------------------------------------

    def on_admit(self, slot_ids: List[int], k_masked, true_lens: np.ndarray,
                 q_last) -> None:
        """Bucketed admission: reset the slots' summary rows, bulk-ship the
        prompt keys (the memory moves to the accelerator at prefill, §5.1),
        seed the stale-query buffer with the last-prompt-token queries."""
        sid = jax.device_put(jnp.asarray(slot_ids, jnp.int32), self.off_dev)
        self.summary = self.sel.reset(self.summary, sid)
        k_off = self.ledger.ship_down(k_masked, self.off_dev, bulk=True)
        q_off = self.ledger.ship_down(q_last, self.off_dev, bulk=True)
        Bg, S = k_off.shape[1], k_off.shape[2]
        self.summary = self._span_fn(Bg, S)(
            self.summary, self.sp_off, k_off, sid,
            jnp.zeros((Bg,), jnp.int32), jnp.asarray(true_lens, jnp.int32))
        self.q_buf = self.q_buf.at[:, sid].set(
            q_off.astype(self.q_buf.dtype))
        self.invalidate()

    def on_admit_slot(self, slot: int) -> None:
        """Chunked admission: clear the slot's rows; keys arrive per chunk."""
        sid = jax.device_put(jnp.asarray([slot], jnp.int32), self.off_dev)
        self.summary = self.sel.reset(self.summary, sid)
        self.q_buf = self.q_buf.at[:, sid].set(0.0)
        self.invalidate()

    def on_extend(self, k_span, q_last, start_np: np.ndarray,
                  n_valid_np: np.ndarray, finished: bool) -> None:
        """Chunked-prefill chunk landed: ingest the span, refresh the
        stale query of every advancing slot. Counted as bulk prefill
        traffic — it is admission-time memory shipping, not the per-step
        decode exchange."""
        k_off = self.ledger.ship_down(k_span, self.off_dev, bulk=True)
        q_off = self.ledger.ship_down(q_last, self.off_dev, bulk=True)
        Bg, S = k_off.shape[1], k_off.shape[2]
        sid = jnp.arange(Bg, dtype=jnp.int32)
        self.summary = self._span_fn(Bg, S)(
            self.summary, self.sp_off, k_off, sid,
            jnp.asarray(start_np, jnp.int32),
            jnp.asarray(n_valid_np, jnp.int32))
        adv = jnp.asarray(n_valid_np > 0)
        self.q_buf = jnp.where(adv[None, :, None, None],
                               q_off.astype(self.q_buf.dtype), self.q_buf)
        if finished:
            self.invalidate()

    def invalidate(self) -> None:
        """Drop the pending lookahead (membership of the pool changed); the
        next decode step cold-starts a fresh selection. Both scheduling
        modes invalidate at the same host events, so determinism holds."""
        self.sel_buf = None
        self._sel_inputs = None

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    def decode(self, params, tok, pool_device: Dict, table,
               lengths_np: np.ndarray, live_np: np.ndarray):
        """One pooled decode step. Returns (logits, {k_pages, v_pages})."""
        sync = self.mode == "sync"
        t_step = time.perf_counter()
        lengths = jnp.asarray(lengths_np, jnp.int32)
        live = jnp.asarray(live_np)
        context = int(lengths_np.max()) + 1 if live_np.any() else 1
        offloaded = hpolicy.dynamic_mode(context, self.mem) == "offload"

        t_sel = 0.0
        if offloaded:
            if self.sel_buf is None:                      # cold start
                t0 = time.perf_counter()
                self.sel_buf = self._launch_select(lengths_np)
                if sync:
                    jax.block_until_ready(self.sel_buf)
                    t_sel += time.perf_counter() - t0
            pidx_inputs = self._sel_inputs
            pidx = self.ledger.ship_up(self.sel_buf, self.main_dev)
        else:
            # dynamic fallback: single-device execution, no offload work
            pidx_inputs, pidx = None, self._neg_sel
            self.invalidate()

        # pin the pre-step offload state for the lookahead (the overlapped
        # select must not see this step's keys/queries)
        summary_prev, q_prev = self.summary, self.q_buf
        next_sel = next_inputs = None
        if offloaded and not sync:
            # queue select_{t+1} BEFORE apply_t: JAX async dispatch runs it
            # on the offload device while the main device decodes
            next_sel = self._launch_select(lengths_np + live_np)
            next_inputs = self._sel_inputs

        if sync:
            jax.block_until_ready(pidx)
        t0 = time.perf_counter()
        logits, pool, q_t, k_t = self._apply_fn(table.shape[1])(
            params, tok, pool_device["k_pages"], pool_device["v_pages"],
            table, lengths, live, pidx)
        if sync:
            jax.block_until_ready(logits)
            t_apply = time.perf_counter() - t0
        else:
            t_apply = None

        if offloaded and sync:
            t0 = time.perf_counter()
            next_sel = self._launch_select(lengths_np + live_np)
            next_inputs = self._sel_inputs
            jax.block_until_ready(next_sel)
            t_sel += time.perf_counter() - t0

        # ship this step's queries/keys down; ingest into the index summary
        # (also during local fallback — the index must stay coherent for
        # when the context re-enters the offload window)
        self.ledger.tick()
        t0 = time.perf_counter()
        q_off = self.ledger.ship_down(q_t, self.off_dev)
        k_off = self.ledger.ship_down(k_t, self.off_dev)
        self.summary = self._ingest_jit(summary_prev, self.sp_off, k_off,
                                        lengths, live)
        self.q_buf = jnp.where(live[None, :, None, None],
                               q_off.astype(q_prev.dtype), q_prev)
        if sync:
            jax.block_until_ready(self.summary)
            if offloaded:   # local-fallback ingest is pool upkeep — not a
                t_sel += time.perf_counter() - t0   # select-phase cost
        self.sel_buf, self._sel_inputs = next_sel, next_inputs

        if self.validate and offloaded and pidx_inputs is not None:
            self._validate(pidx, pidx_inputs)
        self.profiler.record_step(
            int(live_np.sum()), context, time.perf_counter() - t_step,
            select_s=t_sel if sync else None, apply_s=t_apply,
            offloaded=offloaded)
        return logits, pool

    # ------------------------------------------------------------------
    # validation mode
    # ------------------------------------------------------------------

    def _validate(self, pidx, inputs) -> None:
        """Re-run the consumed selection synchronously from its pinned
        inputs: async result must be bit-identical, and every index must be
        a valid stale pick (inside the live region it was computed from)."""
        summary, q, lengths = inputs
        ref = jax.block_until_ready(self._select_jit(self.sp_off, summary,
                                                     q, lengths))
        got = np.asarray(jax.block_until_ready(pidx))
        if not np.array_equal(got, np.asarray(ref)):
            raise AssertionError(
                "overlapped selection diverged from its synchronous replay")
        lens = np.asarray(lengths)
        sel_ok = (got == -1) | ((got >= 0)
                                & (got * self.sel.page < lens[None, :, None]))
        if not sel_ok.all():
            raise AssertionError("stale lookahead produced out-of-window "
                                 "page indices")

    # ------------------------------------------------------------------

    def report(self) -> Dict:
        d = self.profiler.summary(self.ledger, cfg=self.cfg,
                                  n_sel=self.sel.n_sel, page=self.sel.page,
                                  batch=self.sc.n_slots)
        d["devices"] = {"main": str(self.main_dev),
                        "offload": str(self.off_dev),
                        "distinct": self.main_dev != self.off_dev}
        d["plan"] = {"stages": dict(self.plan.stages),
                     "offloaded": list(self.plan.offloaded())}
        return d
