"""Async offload executor: overlaps memory processing with decode (§5).

Two-phase decode with ONE STEP OF LOOKAHEAD, double-buffered across two
JAX devices:

  main device     apply_t (sparse attention over preselected pages + the
                  dense transformer remainder), then ships this step's
                  per-layer queries/keys to the offload device;
  offload device  runs select_{t+1} (prepare/relevancy/retrieve over its
                  incrementally maintained index summary) CONCURRENTLY
                  with apply_t, and ingests step t's keys afterwards.

The selection serving step t therefore saw the queries of step t-2 and the
keys through step t-2 — the stale-lookahead semantics the paper accepts in
exchange for hiding the memory-bound stages entirely (the freshly written
page is force-included at apply time, so recency is never lost).

Scheduling modes share ONE dataflow — every jitted function runs with the
same inputs in the same buffer order — and differ only in barriers:

  "overlap"  no host barriers; JAX async dispatch queues select_{t+1} on
             the offload device while the main device runs apply_t.
  "sync"     block_until_ready between phases: select, apply, ingest run
             serially. This is the honest single-timeline baseline the
             benchmarks compare against.

Because the dataflow is identical, the two modes are bit-identical
(tests/test_hetero.py proves it per method); ``validate=True`` additionally
re-executes every consumed selection synchronously from the pinned inputs
and asserts bitwise equality + stale-index validity, turning any buffer
misuse in the async schedule into an immediate failure.

INVALIDATION IS PER SLOT: pool-membership events (a finished admission, a
drained retrieval splice) mark only the affected slots dirty instead of
discarding the whole pending lookahead. The next decode step still consumes
the overlapped buffer — clean slots keep their lookahead selection, dirty
rows are patched from a fresh selection launched at consumption time. Both
scheduling modes patch at the same host events, so determinism holds, and
retrieval-heavy pools stop paying a cold-start for every splice that lands
(``profiler.lookahead_hits`` vs ``lookahead_cold`` makes the reuse rate
observable; tests/test_hetero_sharded.py pins it).

The selection-state methods (`_launch_select` / `_to_apply` / `_ingest_step`
/ `_patch` / pinned-input plumbing) are the override surface of
``hetero.sharded.ShardedHeteroExecutor``, which runs one summary shard per
offload device and merges per-shard top-k candidates on the main device.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MemoryConfig
from repro.hetero import policy as hpolicy
from repro.hetero.profiler import HeteroProfiler
from repro.hetero.select import make_offload_select
from repro.hetero.transfer import TransferLedger
from repro.models import model as M

PATCHED = "patched"   # tag of composite pinned-input records
FUSED = "fused"       # tag of pinned inputs produced by a fused window
READY = "ready"       # tag of a selection already merged on the apply side


def _is_ready(handle) -> bool:
    """A fused window returns its exit lookahead as a MERGED pidx resident
    on the apply target — no per-shard ship_up/merge left to do."""
    return isinstance(handle, tuple) and len(handle) == 2 \
        and handle[0] == READY


class HeteroExecutor:
    def __init__(self, cfg: ArchConfig, mem: MemoryConfig, sc,
                 sparse_params, *, mode: str = "overlap",
                 validate: bool = False, devices=None, main_mesh=None):
        assert mode in ("sync", "overlap"), mode
        self.cfg, self.mem, self.sc, self.mode = cfg, mem, sc, mode
        self.validate = validate
        self.main_dev, self.off_dev = devices or hpolicy.pick_devices()
        # main side as a MESH: the apply phase runs sequence-parallel over
        # it (distributed_paged_sparse_decode through the page_attn seam).
        # Everything the apply jit consumes must then be committed to the
        # mesh (replicated) rather than to a single main device — a
        # single-device-committed pidx next to mesh-committed pool buffers
        # is a jit device-assignment conflict — so ship_up targets
        # ``_apply_target`` instead of ``main_dev``.
        self.main_mesh = main_mesh
        self._apply_target = self.main_dev
        if main_mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            self._apply_target = NamedSharding(main_mesh, PartitionSpec())
        self.sel = make_offload_select(sc.method, cfg, mem,
                                       dsa_page=sc.page,
                                       n_slots=sc.n_slots,
                                       max_len=sc.max_len)
        self.plan = hpolicy.plan_stage_placement(cfg, mem, sc.max_len)
        self.ledger = TransferLedger()
        self.profiler = HeteroProfiler(cfg, mem, mode)

        self.sel_buf = None            # selection for the NEXT decode step
        self._sel_inputs = None        # pinned inputs of it (validation)
        self._dirty = np.zeros((sc.n_slots,), bool)  # rows needing a patch
        self._neg_sel = jax.device_put(
            jnp.full((cfg.n_layers, sc.n_slots, self.sel.n_sel), -1,
                     jnp.int32), self._apply_target)
        self._init_offload_state(sparse_params)

        self._span_jits: Dict[Tuple, callable] = {}
        self._apply_jits: Dict[int, callable] = {}
        self._fused_jits: Dict[Tuple, callable] = {}
        self._sp_apply_buf = None      # sparse params on the apply target
        self._select_full_jit = None   # full-window select (fused replay)

    def _init_offload_state(self, sparse_params) -> None:
        """Offload-resident state: method params, index summary, stale
        query buffer — one copy on the single offload device."""
        cfg, sc = self.cfg, self.sc
        self.sp_off = jax.device_put(sparse_params, self.off_dev)
        self.summary = jax.device_put(self.sel.summary_init(), self.off_dev)
        from repro.models import layers as L
        hp = cfg.padded_heads(sc.tp)
        self.q_buf = jax.device_put(
            jnp.zeros((cfg.n_layers, sc.n_slots, hp, cfg.hd),
                      L.dtype_of(cfg)), self.off_dev)
        self._select_jit = jax.jit(self.sel.select)
        self._ingest_jit = jax.jit(self.sel.ingest)

    @property
    def devices(self) -> Tuple:
        """(main, offload) — shared with co-resident services (the
        retrieval subsystem places its corpus/banks on the same offload
        device so one two-device environment hosts both)."""
        return self.main_dev, self.off_dev

    # ------------------------------------------------------------------
    # jit builders
    # ------------------------------------------------------------------

    def _apply_fn(self, n_pages_view: int):
        if n_pages_view not in self._apply_jits:
            cfg, mem, sc, ps = self.cfg, self.mem, self.sc, self.sel.page
            page_attn = None
            if self.main_mesh is not None:
                import functools

                from repro.distributed.topk import \
                    distributed_paged_sparse_decode
                page_attn = functools.partial(
                    distributed_paged_sparse_decode, mesh=self.main_mesh,
                    axis="seq")
            # donation stays on under the mesh: the pool buffers are
            # committed replicated (engine._ensure_pool), so input and
            # output shardings match and XLA can update in place
            self._apply_jits[n_pages_view] = jax.jit(
                lambda p, tok, kp, vp, table, lengths, live, pidx:
                M.decode_step_paged_presel(
                    p, cfg, tok,
                    {"k_pages": kp, "v_pages": vp, "page_table": table,
                     "lengths": lengths},
                    live, pidx, mem, page_size=ps, tp=sc.tp,
                    page_attn=page_attn),
                donate_argnums=(2, 3))
        return self._apply_jits[n_pages_view]

    def _span_fn(self, Bg: int, S: int):
        key = (Bg, S)
        if key not in self._span_jits:
            self._span_jits[key] = jax.jit(self.sel.ingest_span)
        return self._span_jits[key]

    # ------------------------------------------------------------------
    # selection-state primitives (overridden by ShardedHeteroExecutor)
    # ------------------------------------------------------------------

    def _launch_select(self, lengths_np: np.ndarray):
        """Queue a selection on the offload device from the CURRENT summary
        and stale-query buffers -> (handle, pinned inputs)."""
        lengths = jnp.asarray(lengths_np, jnp.int32)
        inputs = (self.summary, self.q_buf, lengths)
        return self._select_jit(self.sp_off, *inputs), inputs

    def _to_apply(self, handle, inputs=None):
        """Ship the consumable selection to the apply side as pidx
        [L, B, n_sel] (the index-only up exchange) — a single main device,
        or replicated over the main mesh when the apply is
        sequence-parallel. A READY handle (fused-window exit lookahead) is
        already merged and resident there."""
        if _is_ready(handle):
            return handle[1]
        return self.ledger.ship_up(handle, self._apply_target)

    def _patch(self, old, fresh, dirty_np: np.ndarray):
        """Row-patch a pending selection handle: dirty slots take the fresh
        selection, clean slots keep their overlapped lookahead."""
        d = jnp.asarray(dirty_np)[None, :, None]
        return jax.tree_util.tree_map(lambda a, b: jnp.where(d, b, a),
                                      old, fresh)

    def _pin_state(self):
        """Pre-step offload state refs for the overlapped lookahead (the
        concurrent select must not see this step's keys/queries)."""
        return self.summary, self.q_buf

    def _ingest_step(self, pinned, q_t, k_t, lengths, live):
        """Ship this step's queries/keys down; fold them into the index
        summary and the stale-query buffer."""
        summary_prev, q_prev = pinned
        q_off = self.ledger.ship_down(q_t, self.off_dev)
        k_off = self.ledger.ship_down(k_t, self.off_dev)
        self.summary = self._ingest_jit(summary_prev, self.sp_off, k_off,
                                        lengths, live)
        self.q_buf = self._blend_q(q_prev, q_off, None, live)
        return self.summary

    def _tick(self) -> None:
        self.ledger.tick()

    # -- pinned-input plumbing (shared with the sharded subclass) -------

    def _raw_lengths(self, inputs):
        return inputs[2]

    def _replay_pidx(self, inputs):
        """Synchronously recompute the FINAL pidx a consumed buffer was
        produced from, recursing through row patches. Recursion runs at the
        pidx level (patch-then-merge == merge-then-patch: the candidate
        merge is per-row) so PATCHED composites can nest FUSED pins — the
        exit lookahead of a fused window, replayed as one full-window
        select from the pinned pre-ingest state on the apply target."""
        if isinstance(inputs, tuple) and inputs and inputs[0] == PATCHED:
            _, old, fresh, dirty = inputs
            return self._patch(self._replay_pidx(old),
                               self._replay_pidx(fresh), dirty)
        if isinstance(inputs, tuple) and inputs and inputs[0] == FUSED:
            _, summary, qbuf, la_len = inputs
            return self._sel_full_jit()(self._sp_apply(), summary, qbuf,
                                        la_len)
        return self._handle_to_pidx(self._select_from_pinned(inputs),
                                    inputs)

    def _select_from_pinned(self, inputs):
        summary, q, lengths = inputs
        return self._select_jit(self.sp_off, summary, q, lengths)

    def _pinned_lengths(self, inputs):
        if isinstance(inputs, tuple) and inputs and inputs[0] == PATCHED:
            _, old, fresh, dirty = inputs
            return jnp.where(jnp.asarray(dirty),
                             self._pinned_lengths(fresh),
                             self._pinned_lengths(old))
        if isinstance(inputs, tuple) and inputs and inputs[0] == FUSED:
            return inputs[3]
        return self._raw_lengths(inputs)

    def _handle_to_pidx(self, handle, inputs):
        """Final selection from a (replayed) handle — identity here, the
        candidate merge for the sharded subclass."""
        return handle

    # ------------------------------------------------------------------
    # admission / prefill hooks (keep the offload index coherent)
    # ------------------------------------------------------------------

    @staticmethod
    def _blend_q(q_buf, q_off, sid, keep_q):
        """Stale-query refresh rule, shared with the sharded subclass:
        ``keep_q=None`` overwrites the seeded slots' rows (admission),
        otherwise only rows whose slot advanced this chunk (``keep_q``
        mask) take the new query."""
        if keep_q is None:
            return q_buf.at[:, sid].set(q_off.astype(q_buf.dtype))
        adv = jnp.asarray(keep_q)
        return jnp.where(adv[None, :, None, None],
                         q_off.astype(q_buf.dtype), q_buf)

    def _reset_slots(self, slot_ids: List[int]) -> None:
        sid = jax.device_put(jnp.asarray(slot_ids, jnp.int32), self.off_dev)
        self.summary = self.sel.reset(self.summary, sid)

    def _seed_span(self, slot_ids, k_masked, start_np, n_valid_np, q_last,
                   *, keep_q: np.ndarray = None) -> None:
        """Ship a prompt/chunk key span down (bulk prefill traffic) and fold
        it into the summary; refresh the stale-query buffer (all rows, or
        only ``keep_q`` rows for chunked spans where some slots idled)."""
        sid = jnp.asarray(slot_ids, jnp.int32)
        k_off = self.ledger.ship_down(k_masked, self.off_dev, bulk=True)
        q_off = self.ledger.ship_down(q_last, self.off_dev, bulk=True)
        Bg, S = k_off.shape[1], k_off.shape[2]
        self.summary = self._span_fn(Bg, S)(
            self.summary, self.sp_off, k_off, sid,
            jnp.asarray(start_np, jnp.int32),
            jnp.asarray(n_valid_np, jnp.int32))
        self.q_buf = self._blend_q(self.q_buf, q_off, sid, keep_q)

    def on_admit(self, slot_ids: List[int], k_masked, true_lens: np.ndarray,
                 q_last) -> None:
        """Bucketed admission: reset the slots' summary rows, bulk-ship the
        prompt keys (the memory moves to the accelerator at prefill, §5.1),
        seed the stale-query buffer with the last-prompt-token queries."""
        self._reset_slots(slot_ids)
        Bg = len(slot_ids)
        self._seed_span(slot_ids, k_masked, np.zeros((Bg,), np.int32),
                        true_lens, q_last)
        self.invalidate(slot_ids)

    def on_admit_slot(self, slot: int) -> None:
        """Chunked admission: clear the slot's rows; keys arrive per chunk."""
        self._reset_slots([slot])
        self._clear_q([slot])
        self.invalidate([slot])

    def _clear_q(self, slot_ids: List[int]) -> None:
        sid = jnp.asarray(slot_ids, jnp.int32)
        self.q_buf = self.q_buf.at[:, sid].set(0.0)

    def on_extend(self, k_span, q_last, start_np: np.ndarray,
                  n_valid_np: np.ndarray, finished: List[int]) -> None:
        """Chunked-prefill chunk landed: ingest the span, refresh the
        stale query of every advancing slot. Counted as bulk prefill
        traffic — it is admission-time memory shipping, not the per-step
        decode exchange. ``finished`` lists the slots whose payload
        (admission prompt or retrieval splice) completed this step — only
        THEIR lookahead rows go dirty."""
        Bg = k_span.shape[1]
        self._seed_span(list(range(Bg)), k_span, start_np, n_valid_np,
                        q_last, keep_q=n_valid_np > 0)
        if finished:
            self.invalidate(finished)

    def invalidate(self, slots: List[int] = None) -> None:
        """``slots=None`` drops the whole pending lookahead (the offload
        window itself changed — dynamic fallback); a slot list marks only
        those rows dirty: the next decode step patches them from a fresh
        selection and keeps every clean slot's overlapped lookahead. Both
        scheduling modes invalidate at the same host events, so determinism
        holds."""
        if slots is None:
            self.sel_buf = None
            self._sel_inputs = None
            self._dirty[:] = False
        else:
            self._dirty[list(slots)] = True

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    def _resolve_sel(self, lengths_np: np.ndarray, live_np: np.ndarray,
                     *, sync: bool):
        """Resolve the selection consumed by the NEXT apply: cold-start
        when no lookahead is pending, otherwise reuse it, patching the rows
        of slots whose membership changed. Shared by the stepped schedule
        and the fused-window entry (bit-identical resolution either way).
        Returns (pinned_inputs, pidx, select_wall_s)."""
        t_sel = 0.0
        if self.sel_buf is None:                          # cold start
            t0 = time.perf_counter()
            self.sel_buf, self._sel_inputs = \
                self._launch_select(lengths_np)
            self._dirty &= ~live_np
            self.profiler.lookahead_cold += 1
            if sync:
                jax.block_until_ready(self.sel_buf)
                t_sel += time.perf_counter() - t0
        else:
            self.profiler.lookahead_hits += 1
            patch_rows = self._dirty & live_np
            if patch_rows.any():
                # membership changed for these slots only: patch their
                # rows from a fresh selection, keep the overlapped
                # lookahead of every clean slot
                t0 = time.perf_counter()
                fresh, fresh_inputs = self._launch_select(lengths_np)
                if _is_ready(self.sel_buf):
                    # fused exit lookahead is already a merged pidx: patch
                    # at the pidx level (merge is per-row, so this equals
                    # patching the handles first)
                    self.sel_buf = (READY, self._patch(
                        self.sel_buf[1],
                        self._to_apply(fresh, fresh_inputs), patch_rows))
                else:
                    self.sel_buf = self._patch(self.sel_buf, fresh,
                                               patch_rows)
                self._sel_inputs = (PATCHED, self._sel_inputs,
                                    fresh_inputs, patch_rows.copy())
                self._dirty &= ~patch_rows
                self.profiler.lookahead_patched += 1
                if sync:
                    jax.block_until_ready(self.sel_buf)
                    t_sel += time.perf_counter() - t0
        return self._sel_inputs, self._to_apply(self.sel_buf), t_sel

    def decode(self, params, tok, pool_device: Dict, table,
               lengths_np: np.ndarray, live_np: np.ndarray):
        """One pooled decode step. Returns (logits, {k_pages, v_pages})."""
        sync = self.mode == "sync"
        t_step = time.perf_counter()
        lengths = jnp.asarray(lengths_np, jnp.int32)
        live = jnp.asarray(live_np)
        context = int(lengths_np.max()) + 1 if live_np.any() else 1
        offloaded = hpolicy.dynamic_mode(context, self.mem) == "offload"

        t_sel = 0.0
        if offloaded:
            pidx_inputs, pidx, t_sel = self._resolve_sel(lengths_np,
                                                         live_np, sync=sync)
        else:
            # dynamic fallback: single-device execution, no offload work
            pidx_inputs, pidx = None, self._neg_sel
            self.invalidate()

        # pin the pre-step offload state for the lookahead (the overlapped
        # select must not see this step's keys/queries)
        pinned = self._pin_state()
        next_sel = next_inputs = None
        if offloaded and not sync:
            # queue select_{t+1} BEFORE apply_t: JAX async dispatch runs it
            # on the offload device while the main device decodes
            next_sel, next_inputs = self._launch_select(
                lengths_np + live_np)

        if sync:
            jax.block_until_ready(pidx)
        t0 = time.perf_counter()
        logits, pool, q_t, k_t = self._apply_fn(table.shape[1])(
            params, tok, pool_device["k_pages"], pool_device["v_pages"],
            table, lengths, live, pidx)
        if sync:
            jax.block_until_ready(logits)
            t_apply = time.perf_counter() - t0
        else:
            t_apply = None

        if offloaded and sync:
            t0 = time.perf_counter()
            next_sel, next_inputs = self._launch_select(
                lengths_np + live_np)
            jax.block_until_ready(next_sel)
            t_sel += time.perf_counter() - t0

        # ship this step's queries/keys down; ingest into the index summary
        # (also during local fallback — the index must stay coherent for
        # when the context re-enters the offload window)
        self._tick()
        t0 = time.perf_counter()
        summary_ref = self._ingest_step(pinned, q_t, k_t, lengths, live)
        if sync:
            jax.block_until_ready(summary_ref)
            if offloaded:   # local-fallback ingest is pool upkeep — not a
                t_sel += time.perf_counter() - t0   # select-phase cost
        self.sel_buf, self._sel_inputs = next_sel, next_inputs

        if self.validate and offloaded and pidx_inputs is not None:
            self._validate(pidx, pidx_inputs)
        self.profiler.record_step(
            int(live_np.sum()), context, time.perf_counter() - t_step,
            select_s=t_sel if sync else None, apply_s=t_apply,
            offloaded=offloaded)
        return logits, pool

    # ------------------------------------------------------------------
    # fused multi-step windows (serving.fused)
    # ------------------------------------------------------------------

    def _sp_apply(self):
        """Method params on the apply target (the in-scan select/ingest
        run there for the duration of a fused window)."""
        if self._sp_apply_buf is None:
            src = self.sp_off if hasattr(self, "sp_off") else self.sp_offs[0]
            self._sp_apply_buf = jax.device_put(src, self._apply_target)
        return self._sp_apply_buf

    def _sel_full_jit(self):
        """Full-window select (device-agnostic jit) — the in-scan selection
        and the FUSED-pin validation replay both use it."""
        if self._select_full_jit is None:
            self._select_full_jit = jax.jit(self.sel.select)
        return self._select_full_jit

    def _fused_state_up(self):
        """Ship the offload-resident index state to the apply target for a
        fused window (accounted as bulk traffic — a state migration, not
        the per-step exchange). Returns (summary, q_buf)."""
        summary = self.ledger.ship_down(self.summary, self._apply_target,
                                        bulk=True)
        qbuf = self.ledger.ship_down(self.q_buf, self._apply_target,
                                     bulk=True)
        return summary, qbuf

    def _fused_state_down(self, summary, qbuf):
        """Restore the post-window index state to the offload device(s) so
        the stepped schedule can resume seamlessly."""
        self.summary = self.ledger.ship_down(summary, self.off_dev,
                                             bulk=True)
        self.q_buf = self.ledger.ship_down(qbuf, self.off_dev, bulk=True)

    def _fused_fn(self, n_pages_view: int, K: int, trigger):
        key = (n_pages_view, K, trigger)
        if key not in self._fused_jits:
            page_attn = None
            if self.main_mesh is not None:
                import functools

                from repro.distributed.topk import \
                    distributed_paged_sparse_decode
                page_attn = functools.partial(
                    distributed_paged_sparse_decode, mesh=self.main_mesh,
                    axis="seq")
            from repro.serving.fused import make_fused_presel
            fn = make_fused_presel(self.cfg, self.mem, self.sc, self.sel,
                                   K=K, trigger=trigger,
                                   page_attn=page_attn)
            self._fused_jits[key] = jax.jit(fn, donate_argnums=(3, 4))
        return self._fused_jits[key]

    def decode_fused(self, params, tok_np, pool_device: Dict, table,
                     lengths_np: np.ndarray, live_np: np.ndarray, K: int,
                     *, gen_np, maxnew_np, armed_np, arm_after_np, trigger):
        """Up to K pooled decode steps in ONE jitted scan: the two-phase
        apply + the lookahead double-buffer run entirely on the apply
        target, with early exit (masked iterations) when a slot finishes
        or a retrieval trigger fires. The window enters from the SAME
        resolved selection the stepped schedule would consume and exits
        with the pending lookahead reinstalled (READY pidx + FUSED pins),
        so stepped and fused schedules interleave bit-identically."""
        sync = self.mode == "sync"
        t_step = time.perf_counter()
        context = int(lengths_np.max()) + 1 if live_np.any() else 1
        offloaded = hpolicy.dynamic_mode(context, self.mem) == "offload"
        if offloaded:
            pidx_inputs, pidx, _ = self._resolve_sel(lengths_np, live_np,
                                                     sync=sync)
        else:
            pidx_inputs, pidx = None, self._neg_sel
            self.invalidate()
        summary0, qbuf0 = self._fused_state_up()
        outs = self._fused_fn(table.shape[1], K, trigger)(
            params, self._sp_apply(), jnp.asarray(tok_np),
            pool_device["k_pages"], pool_device["v_pages"], table,
            jnp.asarray(lengths_np, jnp.int32), jnp.asarray(live_np),
            jnp.asarray(gen_np, jnp.int32), jnp.asarray(maxnew_np,
                                                        jnp.int32),
            pidx, jnp.asarray(bool(offloaded)), summary0, qbuf0,
            jnp.asarray(armed_np), jnp.asarray(arm_after_np, jnp.int32))
        if sync:
            jax.block_until_ready(outs)
        if self.validate and offloaded and pidx_inputs is not None:
            # entry selection replayed exactly as in the stepped schedule;
            # the exit lookahead is validated at its consumption (FUSED
            # pins), mid-window selections by the fused-vs-stepped oracle
            self._validate(pidx, pidx_inputs)
        nsteps = int(jax.block_until_ready(outs["nsteps"]))
        emits_np = np.asarray(outs["emits"])
        offl_np = np.asarray(outs["offl"])[:nsteps]
        for _ in range(nsteps):
            self._tick()
        self._fused_state_down(outs["summary"], outs["qbuf"])
        if offl_np.size and not offl_np.all():
            # the stepped schedule calls invalidate() on every fallback
            # step, which clears the dirty rows — replicate that so a
            # pre-window dirty bit cannot outlive a mid-window fallback
            self._dirty[:] = False
        if bool(np.asarray(outs["sel_ok"])):
            self.sel_buf = (READY, outs["sel"])
            self._sel_inputs = (FUSED, outs["prev_summary"],
                                outs["prev_q"], outs["prev_len"])
        else:
            self.invalidate()
        self.profiler.record_fused(
            nsteps, int((emits_np[:nsteps] >= 0).sum()), context,
            time.perf_counter() - t_step,
            offload_steps=int(offl_np.sum()),
            local_steps=nsteps - int(offl_np.sum()))
        return {"k_pages": outs["k_pages"], "v_pages": outs["v_pages"],
                "pending": np.asarray(outs["pending"]), "nsteps": nsteps,
                "emits": emits_np, "fired": np.asarray(outs["fired"])}

    # ------------------------------------------------------------------
    # validation mode
    # ------------------------------------------------------------------

    def _validate(self, pidx, inputs) -> None:
        """Re-run the consumed selection synchronously from its pinned
        inputs: async result must be bit-identical, and every index must be
        a valid stale pick (inside the live region it was computed from)."""
        ref = jax.block_until_ready(self._replay_pidx(inputs))
        got = np.asarray(jax.block_until_ready(pidx))
        if not np.array_equal(got, np.asarray(ref)):
            raise AssertionError(
                "overlapped selection diverged from its synchronous replay")
        lens = np.asarray(self._pinned_lengths(inputs))
        sel_ok = (got == -1) | ((got >= 0)
                                & (got * self.sel.page < lens[None, :, None]))
        if not sel_ok.all():
            raise AssertionError("stale lookahead produced out-of-window "
                                 "page indices")

    # ------------------------------------------------------------------

    def report(self) -> Dict:
        d = self.profiler.summary(self.ledger, cfg=self.cfg,
                                  n_sel=self.sel.n_sel, page=self.sel.page,
                                  batch=self.sc.n_slots)
        d["devices"] = {"main": str(self.main_dev),
                        "offload": str(self.off_dev),
                        "distinct": self.main_dev != self.off_dev}
        if self.main_mesh is not None:
            d["devices"]["main_mesh"] = [
                str(x) for x in self.main_mesh.devices.flat]
        d["plan"] = {"stages": dict(self.plan.stages),
                     "offloaded": list(self.plan.offloaded())}
        return d
