"""Fault-tolerant checkpointing: per-host npz shards + JSON manifest,
atomic rename, retention, and RESHARDING restore (elastic: a checkpoint
written on one mesh restores onto any other mesh/host count).

No orbax dependency — files are plain numpy archives so operators can
inspect/repair them with nothing but python.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, params, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomic checkpoint write: tmp dir + rename. Returns the final path.

    bf16 leaves are stored as uint16 bit patterns (npz has no bf16); the
    manifest records the original dtypes for restore."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    flat = _flatten(params)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(v)
        dtypes[k] = str(a.dtype)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = a.view(np.uint16)
        arrays[k] = a
    np.savez(os.path.join(tmp, "params.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "n_leaves": len(arrays),
        "bytes": int(sum(a.nbytes for a in arrays.values())),
        "dtypes": dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like``; reshard onto ``shardings``
    (any mesh — this is the elastic-restart path)."""
    import ml_dtypes

    path = os.path.join(ckpt_dir, f"step_{step:08d}", "params.npz")
    data = np.load(path)
    dtypes = read_manifest(ckpt_dir, step).get("dtypes", {})
    flat_like = _flatten(like)
    missing = [k for k in flat_like if k not in data.files]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")

    def _load(k):
        a = data[k]
        if dtypes.get(k) == "bfloat16":
            a = a.view(ml_dtypes.bfloat16)
        return jnp.asarray(a)

    restored_flat = {k: _load(k) for k in flat_like}

    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    keys = ["/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
            for p in paths]
    leaves = [restored_flat[k] for k in keys]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def read_manifest(ckpt_dir: str, step: int) -> Dict:
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)
