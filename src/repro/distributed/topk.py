"""Distributed fused relevancy+top-k over a sequence-sharded index.

The paper's PCIe principle — "transfer only the top-k indices" (§5.2) —
becomes the ICI principle: every model-axis shard runs the fused Pallas
kernel over ITS slice of the compressed keys, then the mesh all-gathers only
(k values, k indices) pairs per shard (8 B * k per shard, ~16 KB for k=2048)
and merges locally. All-gathering raw scores would move O(S) bytes; all-
gathering KV would move O(S * kv * hd) — this moves O(k * shards).

``batch_axis`` optionally shards the batch dim over the data axes (decode_32k
layout: batch on data, sequence on model); ``axis`` may be a tuple for the
long-context layout where the sequence spans (data, model) jointly.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels import ops


def _axes_tuple(axis):
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _n_shards(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _shard_index(mesh, axes):
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def distributed_relevancy_topk(
    q: jnp.ndarray,        # [B, Hq, dk]
    keys: jnp.ndarray,     # [B, S, dk]  sharded on S over `axis`
    weights: jnp.ndarray,  # [B, Hq]
    k: int,
    mesh: Mesh,
    axis="model",
    *,
    block: int = 2048,
    batch_axis=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact global top-k with index-only exchange. Returns (vals, idx) in
    GLOBAL sequence coordinates."""
    axes = _axes_tuple(axis)
    n_shards = _n_shards(mesh, axes)
    S = keys.shape[1]
    assert S % n_shards == 0, (S, n_shards)
    local_S = S // n_shards
    k_local = min(k, local_S)
    ba = batch_axis

    def local_fn(q_l, keys_l, w_l):
        shard = _shard_index(mesh, axes)
        vals, idx = ops.relevancy_topk(q_l, keys_l, w_l, k_local, block=block)
        idx = idx + shard * local_S
        # index-only exchange: gather [n_shards, B, k_local] pairs
        vals_g = jax.lax.all_gather(vals, axes)
        idx_g = jax.lax.all_gather(idx, axes)
        B = vals.shape[0]
        vals_f = jnp.moveaxis(vals_g, 0, 1).reshape(B, -1)
        idx_f = jnp.moveaxis(idx_g, 0, 1).reshape(B, -1)
        top_v, pos = jax.lax.top_k(vals_f, min(k, n_shards * k_local))
        top_i = jnp.take_along_axis(idx_f, pos, axis=1)
        if top_v.shape[1] < k:  # pad (can't select more than exist)
            pad = k - top_v.shape[1]
            top_v = jnp.pad(top_v, ((0, 0), (0, pad)), constant_values=-jnp.inf)
            top_i = jnp.pad(top_i, ((0, 0), (0, pad)), constant_values=-1)
        return top_v, top_i

    seq_spec = axes if len(axes) > 1 else axes[0]
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(ba), P(ba, seq_spec, None), P(ba)),
        out_specs=(P(ba), P(ba)),
        check_rep=False,
    )
    return fn(q, keys, weights)


def sharded_page_add(kidx: jnp.ndarray, delta: jnp.ndarray, pg,
                     mesh: Mesh, axis="model", batch_axis=None):
    """Add ``delta`` [B, di] into page ``pg`` of the page-sharded index cache
    ``kidx`` [B, n_pages, di] WITHOUT gathering it: only the shard owning the
    page updates (masked local dynamic-update)."""
    axes = _axes_tuple(axis)
    n_shards = _n_shards(mesh, axes)
    n_pages = kidx.shape[1]
    local_np = n_pages // n_shards
    ba = batch_axis
    seq_spec = axes if len(axes) > 1 else axes[0]

    def local_fn(kx, d, pg_arr):
        shard = _shard_index(mesh, axes)
        lpg = pg_arr[0] - shard * local_np
        ok = (lpg >= 0) & (lpg < local_np)
        idx = jnp.clip(lpg, 0, local_np - 1)
        cur = jax.lax.dynamic_slice(kx, (0, idx, 0),
                                    (kx.shape[0], 1, kx.shape[2]))
        new = cur + jnp.where(ok, 1.0, 0.0) * d[:, None]
        return jax.lax.dynamic_update_slice(kx, new.astype(kx.dtype),
                                            (0, idx, 0))

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(ba, seq_spec, None), P(ba), P()),
        out_specs=P(ba, seq_spec, None),
        check_rep=False,
    )
    return fn(kidx, delta, jnp.asarray(pg, jnp.int32)[None])


def distributed_sparse_decode(
    q: jnp.ndarray,         # [B, Hq, dh]
    k_cache: jnp.ndarray,   # [B, S, KV, dh] sharded on S
    v_cache: jnp.ndarray,
    page_ids: jnp.ndarray,  # [B, P] GLOBAL page ids
    length: jnp.ndarray,    # [B]
    mesh: Mesh,
    axis="model",
    *,
    page_size: int = 64,
    batch_axis=None,
):
    """Sequence-parallel sparse decode: each shard attends to ITS selected
    pages; only (out, lse) pairs cross the mesh (FlashDecoding LSE merge).
    Exchanged bytes: O(B * Hq * dh * n_shards) — independent of S and k.

    Thin dense-contract wrapper over ``distributed_paged_sparse_decode``
    (ONE shard body for both: a second copy of the merge math drifted once
    and could not feed LSE-merging callers) — the LSE is dropped for
    callers that only want the merged output."""
    out, _ = distributed_paged_sparse_decode(
        q, k_cache, v_cache, page_ids, length, mesh, axis,
        page_size=page_size, batch_axis=batch_axis)
    return out


def distributed_paged_sparse_decode(
    q: jnp.ndarray,         # [B, Hq, dh]
    k_cache: jnp.ndarray,   # [B, S, KV, dh] paged-pool VIEW, sharded on S
    v_cache: jnp.ndarray,
    page_ids: jnp.ndarray,  # [B, P] GLOBAL logical page ids, -1 invalid
    lengths: jnp.ndarray,   # [B] per-slot live lengths
    mesh: Mesh,
    axis="model",
    *,
    page_size: int = 64,
    batch_axis=None,
):
    """The ONE LSE-merged sequence-parallel apply core (paper Fig. 6a),
    stated for the SERVING pool contract — the dense per-request layout of
    ``distributed_sparse_decode`` is the special case where lengths are
    broadcast and the view has no holes:

      * ``k_cache``/``v_cache`` are the gathered paged-pool view
        (``kernels.page_pool.pool_gather`` over the slot's page table) —
        positions outside a slot's live region are exact zeros by the
        pool's zero-page invariant, so cutting the view into sequence
        shards never exposes stale data;
      * ``lengths`` is PER SLOT (continuous batching: every slot attends
        at its own offset); each shard clips it to its window;
      * ``page_ids`` may carry ``-1`` holes anywhere (merged sharded
        selections, threshold selection) — holes are masked locally.

    Each shard attends to ITS selected pages only; the mesh exchanges
    (out, lse) pairs — O(B * Hq * dh * n_shards) bytes, independent of S
    and k — and FlashDecoding-merges them. Returns (out [B, Hq, dh],
    lse [B, Hq]), the same contract as ``ops.paged_decode_attention`` so it
    drops into ``models.decode_step_paged_presel``'s ``page_attn`` seam.
    """
    axes = _axes_tuple(axis)
    n_shards = _n_shards(mesh, axes)
    S = k_cache.shape[1]
    assert S % (n_shards * page_size) == 0, (S, n_shards, page_size)
    local_S = S // n_shards
    local_pages = local_S // page_size
    ba = batch_axis

    def local_fn(q_l, kc_l, vc_l, pids, len_g):
        shard = _shard_index(mesh, axes)
        local = pids - shard * local_pages
        mine = (pids >= 0) & (local >= 0) & (local < local_pages)
        local = jnp.where(mine, local, -1)
        len_l = jnp.clip(len_g - shard * local_S, 0, local_S)
        out, lse = ops.paged_decode_attention(
            q_l, kc_l, vc_l, local.astype(jnp.int32), len_l,
            page_size=page_size)
        outs = jax.lax.all_gather(out, axes)   # [n_shards, B, Hq, dh]
        lses = jax.lax.all_gather(lse, axes)
        return ops.lse_merge(outs, lses)

    seq_spec = axes if len(axes) > 1 else axes[0]
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(ba), P(ba, seq_spec, None, None),
                  P(ba, seq_spec, None, None), P(ba), P(ba)),
        out_specs=(P(ba), P(ba)),
        check_rep=False,
    )
    return fn(q, k_cache, v_cache, page_ids, lengths)
