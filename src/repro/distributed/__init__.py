from repro.distributed import (
    sharding,
    topk,
    collectives,
    checkpoint,
    elastic,
    pipeline_parallel,
)
