"""Distributed-optimization helpers: compressed gradient all-reduce with
error feedback, and collective-cost estimation for the napkin math in
EXPERIMENTS.md §Perf.

Cross-pod DP links are the scarcest bandwidth at 512+ chips; compressing the
gradient all-reduce (bf16 or int8 + error feedback) cuts the collective term
proportionally while error feedback keeps convergence unbiased in the long
run (Karimireddy et al., arXiv:1901.09847).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.placement import ICI_BW


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization -> (q, scale)."""
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_grads_with_feedback(grads, residual, mode: str = "bf16"):
    """Apply lossy compression to a gradient pytree with error feedback.

    Returns (compressed-and-decompressed grads to feed the all-reduce in low
    precision, new residual). mode: 'none' | 'bf16' | 'int8'.
    The all-reduce itself happens in the compressed dtype when the caller
    casts before psum; we return the dtype-cast tree so jit sees the narrow
    type on the wire.
    """
    if mode == "none":
        return grads, residual
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if mode == "bf16":
            sent = gf.astype(jnp.bfloat16)
            back = sent.astype(jnp.float32)
        else:
            q, s = compress_int8(gf)
            sent = q  # int8 on the wire
            back = decompress_int8(q, s)
        return back.astype(g.dtype), gf - back

    flat_g, tree = jax.tree.flatten(grads)
    flat_r, _ = jax.tree.flatten(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = tree.unflatten([o[0] for o in outs])
    new_r = tree.unflatten([o[1] for o in outs])
    return new_g, new_r


# ---------------------------------------------------------------------------
# analytic collective costs (ring algorithms) — napkin-math utilities
# ---------------------------------------------------------------------------


def all_reduce_seconds(bytes_per_dev: float, n: int, links: float = ICI_BW):
    """Ring all-reduce: 2 (n-1)/n * bytes over the slowest link."""
    return 2.0 * (n - 1) / max(n, 1) * bytes_per_dev / links


def all_gather_seconds(bytes_per_dev: float, n: int, links: float = ICI_BW):
    return (n - 1) / max(n, 1) * bytes_per_dev * n / links


def reduce_scatter_seconds(bytes_per_dev: float, n: int, links: float = ICI_BW):
    return (n - 1) / max(n, 1) * bytes_per_dev / links
