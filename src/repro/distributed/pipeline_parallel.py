"""Optional GPipe-style pipeline parallelism over the 'pod' axis.

At multi-pod scale the cross-pod links are the thin pipe; PP turns them into
point-to-point boundary-activation transfers (collective_permute) instead of
full gradient all-reduces. The schedule is classic GPipe: M microbatches
flow through ``n_stages`` stage groups; bubble fraction (n_stages-1)/(M +
n_stages - 1).

Implementation: shard_map over the pod axis; each stage owns a
layer-contiguous slice of the (stacked) layer params; boundary activations
move with lax.ppermute inside a fori over (M + n_stages - 1) ticks.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe_forward(
    layer_group_fn: Callable,  # (stage_params, x) -> x
    mesh: Mesh,
    axis: str = "pod",
):
    """Build fn(stage_params_stacked, x_microbatches) -> y_microbatches.

    ``stage_params_stacked`` leading dim = n_stages (sharded over `axis`);
    ``x_microbatches`` [M, mb, ...] replicated; output from the LAST stage.
    """
    n_stages = mesh.shape[axis]

    def local(stage_params, xs):
        # stage_params: this stage's slice (leading dim 1) ; xs [M, mb, ...]
        sp = jax.tree.map(lambda a: a[0], stage_params)
        stage = jax.lax.axis_index(axis)
        M = xs.shape[0]
        ticks = M + n_stages - 1
        buf = jnp.zeros_like(xs)  # holds this stage's outputs per microbatch

        def tick(t, carry):
            inflight, buf = carry  # inflight: activation entering this stage
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < M)
            # stage 0 reads fresh microbatches; others consume the permuted
            src = jnp.where(stage == 0,
                            jnp.clip(t, 0, M - 1),
                            jnp.clip(mb_idx, 0, M - 1))
            x_in = jnp.where(stage == 0, xs[src], inflight)
            y = layer_group_fn(sp, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            buf = jnp.where(active,
                            buf.at[jnp.clip(mb_idx, 0, M - 1)].set(y), buf)
            # ship boundary activation to the next stage
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, buf)

        inflight0 = jnp.zeros_like(xs[0])
        _, buf = jax.lax.fori_loop(0, ticks, tick, (inflight0, buf))
        # only the last stage's buffer is the model output; broadcast it
        # (ppermute is a permutation — multicast needs all_gather + select)
        return jax.lax.all_gather(buf, axis)[n_stages - 1]

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
