"""Elastic scaling + straggler mitigation for 1000+-node operation.

Components:
  * ``StragglerMonitor`` — per-step deadline tracking with EWMA baselines;
    flags hosts whose step time exceeds ``factor``x the fleet median so the
    launcher can evict/replace them (checkpoint + re-mesh).
  * ``plan_mesh`` — given the surviving device count, choose the largest
    valid (data, model) factorization that preserves TP divisibility, so a
    512-chip job degrades to 480 chips instead of dying.
  * ``ElasticSession`` — ties it together: on failure, restore the latest
    checkpoint onto the new mesh (distributed/checkpoint.py reshards).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np


class StragglerMonitor:
    def __init__(self, factor: float = 2.0, window: int = 16):
        self.factor = factor
        self.window = window
        self.history: Dict[str, List[float]] = {}

    def record(self, host: str, step_seconds: float):
        self.history.setdefault(host, []).append(step_seconds)
        self.history[host] = self.history[host][-self.window:]

    def medians(self) -> Dict[str, float]:
        return {h: float(np.median(v)) for h, v in self.history.items() if v}

    def stragglers(self) -> List[str]:
        med = self.medians()
        if len(med) < 2:
            return []
        fleet = float(np.median(list(med.values())))
        return [h for h, m in med.items() if m > self.factor * fleet]

    def deadline(self) -> float:
        med = self.medians()
        if not med:
            return float("inf")
        return self.factor * float(np.median(list(med.values())))


def plan_mesh(n_devices: int, *, model_parallel: int = 16,
              multi_pod: bool = False) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest usable mesh from surviving devices, preserving TP size.

    TP (model axis) must stay fixed — param shards are TP-aligned — so
    elasticity happens on the data/pod axes: use floor(n / tp) data ways.
    """
    tp = model_parallel
    if n_devices < tp:
        raise ValueError(f"need >= {tp} devices for TP={tp}, got {n_devices}")
    dp = n_devices // tp
    if multi_pod and dp % 2 == 0:
        return (2, dp // 2, tp), ("pod", "data", "model")
    return (dp, tp), ("data", "model")


@dataclasses.dataclass
class ElasticEvent:
    time: float
    kind: str       # "straggler" | "failure" | "rescale"
    detail: str


class ElasticSession:
    """Launcher-side state machine: detect -> checkpoint -> re-mesh -> restore."""

    def __init__(self, ckpt_dir: str, model_parallel: int = 16):
        self.ckpt_dir = ckpt_dir
        self.tp = model_parallel
        self.events: List[ElasticEvent] = []
        self.monitor = StragglerMonitor()

    def on_step(self, host: str, seconds: float):
        self.monitor.record(host, seconds)

    def check(self, n_live_devices: int):
        """Returns a new mesh plan if the fleet changed, else None."""
        stragglers = self.monitor.stragglers()
        if stragglers:
            self.events.append(ElasticEvent(time.time(), "straggler",
                                            ",".join(stragglers)))
        return None

    def rescale(self, n_live_devices: int, multi_pod: bool = False):
        shape, axes = plan_mesh(n_live_devices, model_parallel=self.tp,
                                multi_pod=multi_pod)
        self.events.append(ElasticEvent(
            time.time(), "rescale", f"-> mesh {shape} axes {axes}"))
        return shape, axes
