import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (architecture x input-shape x
mesh) cell on placeholder devices, print memory/cost analysis, extract
roofline terms, and cache everything to experiments/dryrun/*.json.

MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun
[--arch A] [--shape S] [--multi-pod] [--variant baseline]``. The XLA_FLAGS
line above executes before any jax import — nothing else in the repo sets it.
"""

import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_arch
from repro.configs.base import ShapeConfig
from repro.kernels import ops
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, pick_accum
from repro.models import model as M
from repro.train.optimizer import OptConfig, init_opt_state, adamw_update

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _cell_path(arch: str, shape: str, multi_pod: bool, variant: str) -> str:
    mesh = "pod2x16x16" if multi_pod else "16x16"
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}__{variant}.json")


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_train_step(cfg, shape, mesh, tp, variant: str):
    """Full train step: fwd + bwd (remat) + AdamW update."""
    data_par = mesh.devices.size // tp
    accum = pick_accum(cfg, shape, data_par)
    from repro.models import moe as _moe
    from repro.models import model as _model
    _moe.set_ep_constraint(None)      # reset variant-gated flags (cells run
    _model.set_sp_residual(None)      # back-to-back in one process)
    if variant.startswith("optimized") and cfg.n_experts \
            and cfg.n_experts % tp == 0:
        _moe.set_ep_constraint("model")  # §Perf: shard-local EP dispatch
    if "sp" in variant.split("-") and shape.seq_len % tp == 0:
        from jax.sharding import PartitionSpec as P
        da = tuple(a for a in mesh.axis_names if a not in ("model",))
        _model.set_sp_residual(P(da, "model", None))  # §Perf: Megatron-SP

    def loss_fn(p, batch):
        return M.train_loss(p, cfg, batch, remat=True, tp=tp)

    def step(params, m, v, batch):
        if accum > 1:
            B = batch["tokens"].shape[0]
            mb = B // accum
            batch = {k: x.reshape((accum, mb) + x.shape[1:])
                     if k != "positions3" else
                     jnp.moveaxis(x.reshape((3, accum, mb) + x.shape[2:]), 0, 1)
                     for k, x in batch.items()}

            def micro(carry, b):
                l_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, b)
                return (l_acc + l / accum,
                        jax.tree.map(lambda a, x: a + x / accum, g_acc, g)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zero), batch)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        from repro.train.optimizer import OptState
        state = OptState(jnp.ones((), jnp.int32), m, v, None)
        new_p, new_s, _ = adamw_update(grads, state, params, OptConfig())
        return loss, new_p, new_s.m, new_s.v

    return step, accum


def build_prefill_step(cfg, shape, tp):
    def step(params, batch):
        logits, caches = M.prefill(
            params, cfg, batch["tokens"], max_len=shape.seq_len,
            positions3=batch.get("positions3"),
            img_embeds=batch.get("img_embeds"), remat=True, tp=tp)
        return logits, caches

    return step


def build_decode_step(cfg, shape, mesh, tp, variant: str):
    """serve_step: ONE new token against a seq_len KV cache (paper pipeline
    active for attention archs — long contexts run sparse, per placement)."""
    sparse_fn = None
    stateful = False
    if cfg.family != "ssm" and shape.seq_len >= cfg.memory.min_context:
        from repro.core.methods import get_sparse_method
        _, mk = get_sparse_method(cfg.memory.method)
        big_batch = shape.global_batch >= mesh.devices.size // tp
        axis = "model" if big_batch else tuple(
            a for a in mesh.axis_names if a != "model") + ("model",)
        batch_axis = (tuple(a for a in mesh.axis_names if a != "model")
                      if big_batch else None)
        if variant == "optimized-spdecode":
            from repro.core.methods.dsa import make_sparse_fn_distributed
            sparse_fn = make_sparse_fn_distributed(
                cfg, cfg.memory, mesh, axis=axis, batch_axis=batch_axis,
                tp=tp, page=64)
        elif variant == "optimized-idxcache":
            from repro.core.methods.dsa import make_sparse_fn_cached
            sparse_fn = make_sparse_fn_cached(
                cfg, cfg.memory, mesh, axis=axis, batch_axis=batch_axis,
                tp=tp, page=64)
            stateful = True
        else:
            kw = {"page": 64} if cfg.memory.method == "dsa" else {}
            sparse_fn = mk(cfg, cfg.memory, tp=tp, **kw)

    def step(params, token, caches, sparse_params):
        return M.decode_step(params, cfg, token, caches, tp=tp,
                             sparse_fn=sparse_fn, sparse_params=sparse_params,
                             sparse_stateful=stateful)

    return step, stateful


# ---------------------------------------------------------------------------
# dry-run one cell
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "baseline", force: bool = False) -> Dict:
    path = _cell_path(arch, shape_name, multi_pod, variant)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    chips = mesh.devices.size
    ops.use_pallas(False)  # dry-run lowers the XLA reference path (DESIGN §6)

    t0 = time.time()
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "variant": variant, "ok": False}
    try:
        # optimized decode keeps weights TP-resident (no FSDP step gathers)
        fsdp = (False if (variant.startswith("optimized")
                          and shape.kind == "decode") else None)
        specs = input_specs(cfg, shape, mesh, tp=tp, fsdp=fsdp)
        from repro.launch.mesh import use_mesh
        with use_mesh(mesh):
            if shape.kind == "train":
                step, accum = build_train_step(cfg, shape, mesh, tp, variant)
                rec["accum"] = accum
                opt_sds = jax.tree.map(
                    lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                    specs["params"])
                fn = jax.jit(
                    step,
                    in_shardings=(specs["params_sharding"],
                                  specs["params_sharding"],
                                  specs["params_sharding"],
                                  specs["batch_sharding"]),
                    donate_argnums=(0, 1, 2),
                )
                lowered = fn.lower(specs["params"], opt_sds, opt_sds,
                                   specs["batch"])
            elif shape.kind == "prefill":
                step = build_prefill_step(cfg, shape, tp)
                fn = jax.jit(step, in_shardings=(specs["params_sharding"],
                                                 specs["batch_sharding"]))
                lowered = fn.lower(specs["params"], specs["batch"])
            else:
                step, stateful = build_decode_step(cfg, shape, mesh, tp,
                                                   variant)
                sp = specs.get("sparse_params")
                sp_shard = specs.get("sparse_sharding")
                if stateful and sp is not None:
                    from jax.sharding import NamedSharding, PartitionSpec as P
                    from repro.core.methods.dsa import idx_cache_init
                    kidx = jax.eval_shape(
                        lambda: idx_cache_init(cfg, cfg.memory,
                                               shape.global_batch,
                                               shape.seq_len, page=64))
                    cspec = jax.tree.leaves(
                        {"k": specs["caches_sharding"]["k"]})[0].spec
                    # pooled index: [L, B, n_pages, di] — batch/seq like KV
                    kidx_shard = NamedSharding(
                        mesh, P(None, cspec[1], cspec[2], None))
                    sp = {"p": sp, "kidx_sum": kidx}
                    sp_shard = {"p": sp_shard, "kidx_sum": kidx_shard}
                shardings = (specs["params_sharding"],
                             specs["batch_sharding"]["token"],
                             specs["caches_sharding"], sp_shard)
                fn = jax.jit(step, in_shardings=shardings,
                             donate_argnums=(2, 3) if stateful else (2,))
                lowered = fn.lower(specs["params"], specs["batch"]["token"],
                                   specs["caches"], sp)
            rec["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t1

        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
        hlo = compiled.as_text()
        rl = RL.from_compiled(compiled, hlo, chips,
                              RL.model_flops_for(cfg, shape))
        rec["roofline"] = rl.to_dict()
        rec["roofline"]["ideal_memory_s"] = (
            RL.ideal_memory_bytes(cfg, shape, chips) / RL.HBM_BW)
        rec["ok"] = True
        print(f"[dryrun] {arch} {shape_name} {rec['mesh']} {variant}: "
              f"compute={rl.compute_s*1e3:.2f}ms memory={rl.memory_s*1e3:.2f}ms "
              f"collective={rl.collective_s*1e3:.2f}ms "
              f"bottleneck={rl.bottleneck} mfu={rl.mfu:.3f} "
              f"(lower {rec['lower_s']:.0f}s compile {rec['compile_s']:.0f}s)")
        print(f"  memory_analysis: { {k: f'{v/2**30:.2f}GiB' for k, v in rec['memory_analysis'].items()} }")
        print(f"  cost_analysis: flops/dev={rl.flops:.3e} bytes/dev={rl.hbm_bytes:.3e}")
        print(f"  collectives: { {k: f'{v/2**20:.1f}MiB' for k, v in rl.per_collective.items() if v} }")
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch} {shape_name} {rec['mesh']} FAILED: {rec['error'][:300]}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_fail = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mp, args.variant, args.force)
                n_ok += rec.get("ok", False)
                n_fail += not rec.get("ok", False)
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
