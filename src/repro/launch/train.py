"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 20 --ckpt-dir /tmp/ck

On a real cluster each host runs this with jax.distributed initialized by the
environment; here it runs single-process. Fault tolerance: checkpoints every
``--ckpt-every`` steps (atomic), auto-resume from the latest, emergency save
on SIGTERM (preemption), straggler monitor wired to the elastic session.
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch
from repro.data import TokenStream
from repro.distributed.elastic import ElasticSession
from repro.models import init_params
from repro.train import OptConfig, TrainConfig, Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--tp", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    tc = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps),
        accum=args.accum, compress=args.compress, tp=args.tp,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    params = init_params(cfg, jax.random.PRNGKey(0), tp=args.tp)
    tr = Trainer(cfg, tc, params)
    elastic = ElasticSession(args.ckpt_dir, model_parallel=args.tp)

    signal.signal(signal.SIGTERM, lambda *_: (tr.emergency_save(),
                                              sys.exit(143)))

    ds = TokenStream(cfg.vocab_size, args.seq, args.batch, seed=0,
                     host_index=jax.process_index(),
                     num_hosts=jax.process_count())
    it = iter(ds)
    for _ in range(tr.step):  # fast-forward the stream after restore
        next(it)
    t_start = time.time()
    while tr.step < args.steps:
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        if args.accum > 1:
            batch = {k: v.reshape((args.accum, v.shape[0] // args.accum)
                                  + v.shape[1:]) for k, v in batch.items()}
        stats = tr.train_step(batch)
        dt = time.time() - t0
        elastic.on_step(f"host{jax.process_index()}", dt)
        if tr.step % 5 == 0 or tr.step == args.steps:
            print(f"step {tr.step:5d} loss {stats['loss']:.4f} "
                  f"lr {stats['lr']:.2e} |g| {stats['grad_norm']:.2f} "
                  f"{dt*1e3:.0f}ms")
    if args.ckpt_dir:
        tr.save()
    print(f"done: {args.steps} steps in {time.time()-t_start:.1f}s; "
          f"stragglers={elastic.monitor.stragglers()}")


if __name__ == "__main__":
    main()
