"""Aggregate cached dry-run JSONs into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report [--mesh 16x16] [--variant baseline]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def load(mesh: str, variant: str):
    from repro.configs import SHAPES, get_arch
    from repro.launch import roofline as RL

    rows = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, f"*__{mesh}__{variant}.json"))):
        r = json.load(open(f))
        if r.get("ok"):
            # recompute derived quantities (TPU-realistic bottleneck/MFU use
            # the analytic ideal-memory LOWER bound; the walker's bytes are a
            # fusion-boundary UPPER bound from the CPU-lowered module)
            rl = r["roofline"]
            cfg, shape = get_arch(r["arch"]), SHAPES[r["shape"]]
            chips = rl["chips"]
            rl["ideal_memory_s"] = (RL.ideal_memory_bytes(cfg, shape, chips)
                                    / RL.HBM_BW)
            terms = {"compute": rl["compute_s"],
                     "memory": rl["ideal_memory_s"],
                     "collective": rl["collective_s"]}
            rl["bottleneck_tpu"] = max(terms, key=terms.get)
            step = max(terms.values())
            rl["step_s_tpu"] = step
            rl["mfu_tpu"] = (rl["model_flops"] / (step * chips * RL.PEAK_FLOPS)
                             if step else 0.0)
        rows.append(r)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(rows, show_memory_analysis=False):
    hdr = ("| arch | shape | compute | memory lo..hi | collective | "
           "bottleneck | useful | MFU | dominant collective |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                       f"{r.get('error', '?')[:60]} |" + " |" * 6)
            continue
        rl = r["roofline"]
        per = rl.get("per_collective", {})
        dom = max(per, key=per.get) if any(per.values()) else "-"
        dom_s = f"{dom} {per.get(dom, 0)/2**30:.2f}GiB" if dom != "-" else "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['compute_s'])} | "
            f"{fmt_s(rl['ideal_memory_s'])}..{fmt_s(rl['memory_s'])} | "
            f"{fmt_s(rl['collective_s'])} | {rl['bottleneck_tpu']} | "
            f"{rl['useful_ratio']:.2f} | {rl['mfu_tpu']:.3f} | {dom_s} |")
    return "\n".join(out)


def pick_hillclimb(rows):
    """The three §Perf cells: worst MFU train cell, most collective-bound,
    most paper-representative (long-context sparse decode)."""
    ok = [r for r in rows if r.get("ok")]
    train = [r for r in ok if r["shape"] == "train_4k"]
    worst = min(train, key=lambda r: r["roofline"]["mfu_tpu"])
    ratio = lambda r: (r["roofline"]["collective_s"]
                       / max(r["roofline"]["compute_s"], 1e-12))
    collective = max(ok, key=ratio)
    longs = [r for r in ok if r["shape"] == "long_500k"
             and r["arch"] not in ("xlstm-125m", "zamba2-7b")]
    paperish = max(longs, key=ratio)
    return worst, collective, paperish


def summary(rows):
    ok = [r for r in rows if r.get("ok")]
    fail = [r for r in rows if not r.get("ok")]
    bn = {}
    for r in ok:
        bn[r["roofline"]["bottleneck"]] = bn.get(r["roofline"]["bottleneck"], 0) + 1
    return (f"{len(ok)} ok / {len(fail)} failed; bottleneck histogram: {bn}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args(argv)
    rows = load(args.mesh, args.variant)
    print(f"## Dry-run roofline — mesh {args.mesh}, variant {args.variant}")
    print(summary(rows))
    print()
    print(table(rows))


if __name__ == "__main__":
    main()
