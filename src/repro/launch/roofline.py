"""Roofline-term extraction from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
  memory term     = HLO_bytes / (chips x 819 GB/s)
  collective term = collective_bytes / (chips x 50 GB/s/link)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(); collective bytes
are NOT in cost_analysis — we parse the optimized HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.placement import PEAK_FLOPS, HBM_BW, ICI_BW

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:[0-9]+)?|pred)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in an HLO result spec."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-op byte totals from optimized HLO text.

    Counts each op's RESULT shape bytes (for all-reduce == payload; for
    all-gather == the gathered output, the wire-dominant size)."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "%name = TYPE[SHAPE] all-gather(...)" and fusion-wrapped forms
        m = re.search(r"=\s*(\S+)\s+(all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)", s)
        if not m:
            continue
        result_spec = m.group(1)
        op = m.group(2)
        out[op] += _shape_bytes(result_spec)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective bytes
    chips: int
    model_flops: float = 0.0     # 6*N*D useful flops (global)
    per_collective: Dict[str, int] = dataclasses.field(default_factory=dict)
    xla_flops: float = 0.0       # raw cost_analysis (cross-check only)
    xla_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / total HLO flops across chips (remat/redundancy)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-flops utilization at the roofline lower bound."""
        denom = self.step_s * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio, "mfu": self.mfu,
            "per_collective": self.per_collective,
            "xla_flops": self.xla_flops, "xla_bytes": self.xla_bytes,
        }


def from_compiled(compiled, hlo_text: str, chips: int,
                  model_flops: float = 0.0) -> Roofline:
    """Roofline terms from the trip-count-aware HLO walk (hlo_walk.py);
    xla cost_analysis kept as a cross-check (it single-counts nested scan
    bodies, so the walker is authoritative — see EXPERIMENTS.md §Method)."""
    from repro.launch import hlo_walk

    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    costs = hlo_walk.walk(hlo_text)
    rl = Roofline(
        flops=costs.flops, hbm_bytes=costs.bytes,
        coll_bytes=costs.coll_bytes, chips=chips,
        model_flops=model_flops,
        per_collective={k: int(v) for k, v in costs.per_collective.items()},
    )
    rl.xla_flops = float(ca.get("flops", 0.0))
    rl.xla_bytes = float(ca.get("bytes accessed", 0.0))
    return rl


def ideal_memory_bytes(cfg, shape, chips: int) -> float:
    """Analytic LOWER BOUND on per-device HBM traffic per step (perfect
    fusion). The walker's bytes term is the fusion-boundary UPPER bound from
    the CPU-lowered module (TPU fuses more aggressively); the table reports
    both. Components: weight reads (fwd+bwd+remat), optimizer read/write,
    residual activations, KV/index traffic for decode."""
    P = cfg.n_params()
    Pa = cfg.n_active_params()
    tokens = shape.global_batch * shape.seq_len
    d, L = cfg.d_model, cfg.n_layers
    act = 4 * tokens * d * L * 2  # residual write+read, fwd+bwd, bf16
    if shape.kind == "train":
        total = 3 * 2 * Pa * max(tokens / (tokens), 1) + 16 * P + act
        # 3 weight passes (fwd/bwd/remat) bf16 + grads/m/v fp32 rw
    elif shape.kind == "prefill":
        kv = L * tokens * cfg.n_kv_heads * cfg.hd * 2 * 2
        total = 2 * Pa + act / 4 + kv
    else:
        B = shape.global_batch
        ctx = shape.seq_len
        if cfg.family == "ssm":
            state = L * B * 2 * cfg.d_model * cfg.d_model // max(cfg.n_heads, 1)
            total = 2 * Pa * 1 + state * 2
        else:
            k = cfg.memory.top_k
            idx = B * ctx * cfg.memory.index_dim * 2 * L      # stream index
            gather = B * k * cfg.n_kv_heads * cfg.hd * 2 * 2 * L
            total = 2 * Pa + idx + gather
    return total / chips


def model_flops_for(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode D = batch tokens (1 step)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
