"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

No device allocation happens here: params/caches come from jax.eval_shape
over the real builders, inputs are constructed directly. The same pattern as
shannon/kernels — weak-type-correct, shardable, zero bytes.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.methods import get_sparse_method
from repro.distributed import sharding as sh
from repro.models import model as M


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def pick_accum(cfg: ArchConfig, shape: ShapeConfig, data_par: int,
               budget_bytes: float = 4e9) -> int:
    """Gradient-accumulation factor bounding per-device remat residuals
    (L x tokens_dev x d_model x 2B) to ~budget."""
    tokens_dev = shape.global_batch * shape.seq_len / max(data_par, 1)
    resid = cfg.n_layers * tokens_dev * cfg.d_model * 2
    accum = 1
    while resid / accum > budget_bytes and accum < shape.global_batch:
        accum *= 2
    while shape.global_batch % accum:
        accum //= 2
    return max(accum, 1)


def param_structs(cfg: ArchConfig, tp: int = 16):
    return jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0), tp=tp))


def cache_structs(cfg: ArchConfig, batch: int, max_len: int, tp: int = 16):
    return jax.eval_shape(lambda: M.make_cache(cfg, batch, max_len, tp=tp))


def sparse_structs(cfg: ArchConfig, tp: int = 16):
    if cfg.family == "ssm":
        return None
    init_fn, _ = get_sparse_method(cfg.memory.method if cfg.memory.method in
                                   ("dsa", "seer", "lserve") else "dsa")
    return jax.eval_shape(
        lambda: init_fn(jax.random.PRNGKey(0), cfg, cfg.memory,
                        stacked=cfg.family != "hybrid"))


def batch_structs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    out: Dict = {}
    if shape.kind == "train":
        out["tokens"] = sds((B, S), jnp.int32)
        out["labels"] = sds((B, S), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = sds((B, S), jnp.int32)
    else:
        out["token"] = sds((B,), jnp.int32)
    if cfg.rope_style == "mrope" and shape.kind != "decode":
        out["positions3"] = sds((3, B, S), jnp.int32)
    if cfg.frontend == "vision_stub" and shape.kind != "decode":
        out["img_embeds"] = sds((B, min(256, S // 4), cfg.d_model),
                                jnp.bfloat16)
    return out


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                tp: int = 16, fsdp: Optional[bool] = None) -> Dict:
    """Everything dryrun needs: structs + shardings per cell.

    ``fsdp``: None = auto (params >= 5B). The optimized decode variant passes
    False — weights stay TP-resident instead of being re-gathered every step
    (§Perf iteration 2)."""
    out: Dict = {"kind": shape.kind}
    pspec = sh.param_specs(param_structs(cfg, tp), cfg, mesh, fsdp=fsdp)
    out["params"] = param_structs(cfg, tp)
    out["params_sharding"] = sh.make_shardings(pspec, mesh)
    out["batch"] = batch_structs(cfg, shape)
    bspec = sh.batch_specs(cfg, shape, mesh)
    out["batch_sharding"] = {
        k: NamedSharding(mesh, bspec[k]) for k in out["batch"]
        if k in bspec
    }
    # decode shapes carry the KV cache / state
    if shape.kind == "decode":
        caches = cache_structs(cfg, shape.global_batch, shape.seq_len, tp)
        out["caches"] = caches
        cspec = sh.cache_specs(caches, cfg, shape, mesh)
        out["caches_sharding"] = sh.make_shardings(cspec, mesh)
        sp = sparse_structs(cfg, tp)
        if sp is not None and cfg.family != "ssm":
            out["sparse_params"] = sp
            out["sparse_sharding"] = sh.make_shardings(
                sh.method_specs(sp, cfg, mesh), mesh)
    return out
