# Launch layer: mesh construction, dry-run, roofline extraction, CLI drivers.
# NOTE: do NOT import dryrun here — it sets XLA_FLAGS at import time and must
# only be imported as the __main__ module of a dedicated process.
