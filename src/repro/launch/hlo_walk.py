"""Trip-count-aware HLO cost walker.

``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE, which
under-reports flops/bytes/collectives by the trip count (layers x accum x
chunks for this codebase). This walker parses the optimized HLO text,
recovers each while's trip count from the integer bound in its condition
computation, and accumulates:

  * dot FLOPs        — 2 * prod(result dims) * contraction size, from the
                       lhs shape + lhs_contracting_dims attribute
  * memory bytes     — sum of (operands + results) of top-level materialized
                       ops (fusion internals excluded: fusions don't
                       materialize intermediates, matching XLA's execution)
  * collective bytes — result sizes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute

scaled by the product of enclosing trip counts; conditionals take the max
over branches (one branch executes).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b(pred|[a-z]+\d+)\[([0-9,]*)\]")
# "%name = <result-spec> opcode(...)", result-spec may be a tuple
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
                       r"([a-z][\w\-]*)\((.*)$")

NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "reshape",  # usually free (layout-preserving at top level post-opt)
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _spec_bytes(spec: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(spec):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _dims(spec: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(spec)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    result_spec: str
    op: str
    rest: str  # operand list + attrs (may span to end of line)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]  # instr name -> result spec


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*)?\{\s*$", line)
            if m and ("{" in line):
                cur = Computation(m.group(1), [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.result_spec
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _attr_comp(rest: str, attr: str) -> Optional[str]:
    m = re.search(attr + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _branch_comps(rest: str) -> List[str]:
    m = re.search(r"branch_computations=\{([^}]*)\}", rest)
    if not m:
        return []
    return [b.strip().lstrip("%") for b in m.group(1).split(",") if b.strip()]


def _operand_names(rest: str) -> List[str]:
    # operands are the leading %refs before the closing paren of the op call
    head = rest.split(")")[0]
    return re.findall(r"%([\w.\-]+)", head)


def trip_count(cond: Computation) -> int:
    """Max integer constant in the condition computation (loop bound)."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.op + "(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
        m = re.search(r"constant\((-?\d+)\)", ins.rest)
        if m:
            best = max(best, int(m.group(1)))
    return max(best, 1)


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    ops = _operand_names(ins.rest)
    if not ops:
        return 0.0
    lhs_spec = shapes.get(ops[0], "")
    lhs_dims = _dims(lhs_spec)
    res_dims = _dims(ins.result_spec) or []
    m = re.search(r"lhs_contracting_dims=\{([^}]*)\}", ins.rest)
    if lhs_dims is None or not m:
        return 0.0
    k = 1
    for d in m.group(1).split(","):
        if d.strip():
            idx = int(d)
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    out = 1
    for d in res_dims:
        out *= d
    return 2.0 * out * k


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Costs", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.coll_bytes += other.coll_bytes * scale
        for k, v in other.per_collective.items():
            self.per_collective[k] += v * scale


def _comp_costs(comp: Computation, comps: Dict[str, Computation],
                memo: Dict[str, Costs]) -> Costs:
    if comp.name in memo:
        return memo[comp.name]
    c = Costs()
    memo[comp.name] = c  # pre-insert (cycles can't happen in HLO, but safe)
    for ins in comp.instrs:
        if ins.op == "while":
            body = _attr_comp(ins.rest, "body")
            cond = _attr_comp(ins.rest, "condition")
            trips = trip_count(comps[cond]) if cond and cond in comps else 1
            if body and body in comps:
                c.add(_comp_costs(comps[body], comps, memo), trips)
            continue
        if ins.op == "conditional":
            branches = _branch_comps(ins.rest)
            branch_costs = [
                _comp_costs(comps[b], comps, memo) for b in branches
                if b in comps
            ]
            if branch_costs:
                worst = max(branch_costs, key=lambda x: max(
                    x.flops, x.bytes, x.coll_bytes))
                c.add(worst)
            continue
        if ins.op == "fusion":
            callee = _attr_comp(ins.rest, "calls")
            if callee and callee in comps:
                # dots inside the fusion still hit the MXU
                inner = _comp_costs(comps[callee], comps, memo)
                c.flops += inner.flops
            # memory traffic: fusion boundary only (operands + result)
            c.bytes += _spec_bytes(ins.result_spec)
            for o in _operand_names(ins.rest):
                c.bytes += _spec_bytes(comp.shapes.get(o, ""))
            continue
        if ins.op in ("dot", "convolution"):
            c.flops += _dot_flops(ins, comp.shapes)
            c.bytes += _spec_bytes(ins.result_spec)
            for o in _operand_names(ins.rest):
                c.bytes += _spec_bytes(comp.shapes.get(o, ""))
            continue
        if ins.op in COLLECTIVES or any(ins.op.startswith(k + "-start")
                                        for k in COLLECTIVES):
            base = ins.op.replace("-start", "")
            b = _spec_bytes(ins.result_spec)
            c.coll_bytes += b
            if base in c.per_collective:
                c.per_collective[base] += b
            c.bytes += b  # collectives also touch HBM
            continue
        if ins.op in NO_TRAFFIC or ins.op.endswith("-done"):
            continue
        # other materialized ops (copy, gather, scatter, dynamic-slice, ...)
        c.bytes += _spec_bytes(ins.result_spec)
        for o in _operand_names(ins.rest):
            c.bytes += _spec_bytes(comp.shapes.get(o, ""))
    memo[comp.name] = c
    return c


def walk(hlo: str) -> Costs:
    comps = parse_computations(hlo)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: the computation with most instructions
        entry = max(comps, key=lambda k: len(comps[k].instrs))
    # exclude condition computations / to_apply reducers from double count:
    # they're only reached via while/fusion edges above, so walking entry
    # alone is correct.
    return _comp_costs(comps[entry], comps, {})
