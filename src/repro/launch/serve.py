"""Serving launcher CLI — batched requests through the continuous-batching
scheduler with the memory pipeline enabled.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --method dsa --requests 8

``--disaggregate`` demonstrates the paper's prefill/decode role split
(Fig. 6b): the mesh's data axis is partitioned into prefill/decode submeshes
(on this CPU container both resolve to the same device; the mesh plumbing is
exercised either way).

``--offload on`` serves through the hetero offload executor (overlapped
lookahead selection on a second device, src/repro/hetero) and prints its
per-stage overhead breakdown; launch with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` for a real split.

``--main-mesh N`` (with ``--offload``) runs the apply phase itself
sequence-parallel over an N-device main mesh — LSE-merged
``distributed_paged_sparse_decode`` behind the engine's ``page_attn`` seam;
composes with ``--offload-shards M`` for the full M-selection x N-apply
topology under ``XLA_FLAGS=--xla_force_host_platform_device_count=N+M``.

``--retrieval on`` enables the document-memory service (src/repro/retrieval):
per-slot FLARE triggers over the decode logits, retrieved documents (or MaC
memory embeddings with ``--retrieval-kind mac``) spliced into the paged pool
overlapped against decode. Composes with ``--offload``.

``--replicas N`` serves the same request stream through a :class:`Router`
over N engine replicas, each pinned to its own device group, sharing one
retrieval corpus — the fleet-scale front of the same request-level API.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import init_params
from repro.serving import Engine, OffloadConfig, Request, Router, \
    ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--method", default="dsa",
                    choices=["none", "dsa", "seer", "lserve"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--disaggregate", action="store_true")
    ap.add_argument("--offload", default="off",
                    choices=["on", "off", "sync", "overlap"],
                    help="hetero offload executor (on = overlap)")
    ap.add_argument("--offload-shards", type=int, default=1,
                    help="KV-sequence shards on the offload side (one "
                         "device per shard; launch with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N+1)")
    ap.add_argument("--main-mesh", type=int, default=1,
                    help="devices in the MAIN apply mesh (sequence-"
                         "parallel LSE-merged apply; composes with "
                         "--offload-shards: launch with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N+M)")
    ap.add_argument("--offload-validate", action="store_true",
                    help="replay every consumed lookahead selection "
                         "synchronously and bit-check it")
    ap.add_argument("--fused-steps", type=int, default=1,
                    help="decode steps fused into one on-device lax.scan "
                         "per host dispatch (1 = stepped host loop)")
    ap.add_argument("--retrieval", default="off",
                    choices=["on", "off", "inline", "sync", "overlap"],
                    help="document-memory service (on = overlap)")
    ap.add_argument("--retrieval-kind", default="rag",
                    choices=["rag", "mac"])
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a Router over N engine replicas, "
                         "each pinned to its own device group (launch "
                         "with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=K*N for a real split); rag "
                         "retrieval shares ONE corpus across the fleet")
    ap.add_argument("--docs", type=int, default=2048,
                    help="synthetic corpus size for --retrieval-kind rag")
    args = ap.parse_args(argv)
    from repro.hetero import resolve_cli_offload, resolve_cli_retrieval
    try:
        offload = resolve_cli_offload(args.offload, args.method)
        ret_mode = resolve_cli_retrieval(args.retrieval)
    except ValueError as e:
        ap.error(str(e))

    cfg = get_arch(args.arch).smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=args.tp)
    if args.disaggregate and jax.device_count() >= 2:
        from repro.launch.mesh import make_mesh, split_mesh_roles
        mesh = make_mesh((jax.device_count() // 1, 1), ("data", "model"))
        pre, dec = split_mesh_roles(mesh)
        print(f"disaggregated roles: prefill={pre.devices.size} devices, "
              f"decode={dec.devices.size} devices")
    retrieval = None
    if ret_mode:
        from repro.core.methods.mac import MacConfig
        from repro.retrieval import RetrievalConfig
        if args.retrieval_kind == "rag":
            from repro.data import build_corpus
            corpus = build_corpus(args.docs, retrieval_vocab=1024,
                                  doc_max=16, gen_vocab=cfg.vocab_size,
                                  seed=0)
            retrieval = RetrievalConfig(kind="rag", mode=ret_mode,
                                        corpus=corpus, k=2,
                                        min_interval=4, max_retrievals=2)
        else:
            retrieval = RetrievalConfig(
                kind="mac", mode=ret_mode, min_interval=4, max_retrievals=2,
                mac=MacConfig(segment_len=16, memory_slots=8, retrieve_k=2))
    extra = 96 if retrieval is not None else 16
    offload_cfg = OffloadConfig(
        mode=offload, validate=args.offload_validate,
        shards=args.offload_shards if offload != "off" else 1,
        main_mesh=args.main_mesh if offload != "off" else 1)
    sc = ServeConfig(max_len=args.prompt_len + args.max_new + extra,
                     n_slots=args.slots, method=args.method,
                     tp=args.tp, page=8, offload_cfg=offload_cfg,
                     fused_steps=args.fused_steps, retrieval=retrieval)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len), args.max_new)
            for i in range(args.requests)]
    if args.replicas > 1:
        front = Router.build(cfg, params, sc, n_replicas=args.replicas,
                             key=jax.random.PRNGKey(1))
        engines = [r.engine for r in front.replicas]
    else:
        eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(1))
        front, engines = eng, [eng]
    t0 = time.perf_counter()
    handles = [front.submit(r) for r in reqs]
    done = front.drain()
    wall = time.perf_counter() - t0
    toks = sum(len(h.tokens) for h in handles)
    ttft = [h.ttft_s() for h in handles if h.ttft_s() is not None]
    shards = args.offload_shards if offload != "off" else 1
    mesh_n = args.main_mesh if offload != "off" else 1
    print(f"method={args.method} offload={offload}"
          f"{f'/shards={shards}' if shards > 1 else ''}"
          f"{f'/mesh={mesh_n}' if mesh_n > 1 else ''} "
          f"retrieval={ret_mode or 'off'}"
          f"{f' replicas={args.replicas}' if args.replicas > 1 else ''}: "
          f"{len(done)}/{args.requests} requests, "
          f"{toks} tokens, {toks / wall:.1f} tok/s, "
          f"p50 TTFT {1e3 * float(np.median(ttft)):.1f}ms")
    if args.replicas > 1:
        print("router report:")
        print(json.dumps(front.report(), indent=2, sort_keys=True))
    if args.fused_steps > 1:
        hs = sum(e.stats["host_steps"] for e in engines)
        ds = sum(e.stats["decode_steps"] for e in engines)
        print(f"fused decode: {ds} device steps in {hs} host dispatches "
              f"({ds / max(hs, 1):.1f} steps/dispatch)")
    for i, e in enumerate(engines):
        tag = f" (replica {i})" if len(engines) > 1 else ""
        if e.hetero is not None:
            print(f"hetero per-stage breakdown{tag} (Fig. 3 style):")
            print(json.dumps(e.hetero.report(), indent=2, sort_keys=True))
        if e.retrieval is not None:
            print(f"retrieval service report{tag}:")
            print(json.dumps(e.retrieval.report(), indent=2,
                             sort_keys=True))


if __name__ == "__main__":
    main()
