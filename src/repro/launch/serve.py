"""Serving launcher CLI — batched requests through the continuous-batching
scheduler with the memory pipeline enabled.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --method dsa --requests 8

``--disaggregate`` demonstrates the paper's prefill/decode role split
(Fig. 6b): the mesh's data axis is partitioned into prefill/decode submeshes
(on this CPU container both resolve to the same device; the mesh plumbing is
exercised either way).

``--offload on`` serves through the hetero offload executor (overlapped
lookahead selection on a second device, src/repro/hetero) and prints its
per-stage overhead breakdown; launch with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` for a real split.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import init_params
from repro.serving import Engine, ServeConfig, Scheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--method", default="dsa",
                    choices=["none", "dsa", "seer", "lserve"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--disaggregate", action="store_true")
    ap.add_argument("--offload", default="off",
                    choices=["on", "off", "sync", "overlap"],
                    help="hetero offload executor (on = overlap)")
    args = ap.parse_args(argv)
    from repro.hetero import resolve_cli_offload
    try:
        offload = resolve_cli_offload(args.offload, args.method)
    except ValueError as e:
        ap.error(str(e))

    cfg = get_arch(args.arch).smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=args.tp)
    if args.disaggregate and jax.device_count() >= 2:
        from repro.launch.mesh import make_mesh, split_mesh_roles
        mesh = make_mesh((jax.device_count() // 1, 1), ("data", "model"))
        pre, dec = split_mesh_roles(mesh)
        print(f"disaggregated roles: prefill={pre.devices.size} devices, "
              f"decode={dec.devices.size} devices")
    eng = Engine(cfg, params,
                 ServeConfig(max_len=args.prompt_len + args.max_new + 16,
                             n_slots=args.slots, method=args.method,
                             tp=args.tp, page=8, offload=offload),
                 key=jax.random.PRNGKey(1))
    sch = Scheduler(eng)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        sch.submit(rng.integers(0, cfg.vocab_size, size=args.prompt_len),
                   max_new=args.max_new)
    done = sch.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in done.values())
    print(f"method={args.method} offload={offload}: "
          f"{len(done)}/{args.requests} requests, "
          f"{toks} tokens, {toks / wall:.1f} tok/s")
    if eng.hetero is not None:
        print("hetero per-stage breakdown (Fig. 3 style):")
        print(json.dumps(eng.hetero.report(), indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
