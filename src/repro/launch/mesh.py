"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def split_mesh_roles(mesh: Mesh, prefill_fraction: float = 0.5):
    """Prefill/decode disaggregation (paper Fig. 6b): partition the data axis
    into a prefill submesh and a decode submesh. Returns (prefill, decode)."""
    devices = mesh.devices  # [..., data, model]
    n_data = mesh.shape["data"]
    cut = max(1, int(n_data * prefill_fraction))
    axes = mesh.axis_names
    d_idx = axes.index("data")
    sl_pre = [slice(None)] * devices.ndim
    sl_dec = [slice(None)] * devices.ndim
    sl_pre[d_idx] = slice(0, cut)
    sl_dec[d_idx] = slice(cut, n_data)
    pre = Mesh(devices[tuple(sl_pre)], axes,
               axis_types=(AxisType.Auto,) * len(axes))
    dec = Mesh(devices[tuple(sl_dec)], axes,
               axis_types=(AxisType.Auto,) * len(axes))
    return pre, dec
