"""Production mesh construction (version-portable).

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization.

``jax.sharding.AxisType`` / explicit ``axis_types`` only exist in newer JAX;
on older releases every mesh axis is implicitly Auto, so the guarded kwargs
degrade to a plain ``jax.make_mesh``/``Mesh`` call. ``use_mesh`` papers over
the ``jax.set_mesh`` (new) vs ``with mesh:`` (old) context difference the
same way. Tests that spawn multi-device subprocesses import these helpers
instead of touching ``AxisType`` directly.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import Mesh

try:  # JAX >= 0.5-era explicit axis types
    from jax.sharding import AxisType

    def _auto_kwargs(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:  # older JAX: all axes are Auto already
    AxisType = None

    def _auto_kwargs(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kwargs(len(axes)))


def make_mesh(shape, axes) -> Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_auto_kwargs(len(axes)))


def mesh_from_devices(devices, axes=("seq",)) -> Mesh:
    """Mesh over an EXPLICIT 1-D device list (``jax.make_mesh`` always
    starts from device 0 — the serving engine's main mesh must instead
    claim specific devices so offload shards can round-robin over the
    rest)."""
    import numpy as np

    return Mesh(np.array(devices), tuple(axes), **_auto_kwargs(len(axes)))


def use_mesh(mesh: Mesh):
    """Context manager that activates ``mesh`` for jitted computations:
    ``jax.set_mesh`` where it exists, the classic ``with mesh:`` otherwise."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext() if mesh is None else mesh


def split_mesh_roles(mesh: Mesh, prefill_fraction: float = 0.5):
    """Prefill/decode disaggregation (paper Fig. 6b): partition the data axis
    into a prefill submesh and a decode submesh. Returns (prefill, decode)."""
    devices = mesh.devices  # [..., data, model]
    n_data = mesh.shape["data"]
    cut = max(1, int(n_data * prefill_fraction))
    axes = mesh.axis_names
    d_idx = axes.index("data")
    sl_pre = [slice(None)] * devices.ndim
    sl_dec = [slice(None)] * devices.ndim
    sl_pre[d_idx] = slice(0, cut)
    sl_dec[d_idx] = slice(cut, n_data)
    pre = Mesh(devices[tuple(sl_pre)], axes, **_auto_kwargs(len(axes)))
    dec = Mesh(devices[tuple(sl_dec)], axes, **_auto_kwargs(len(axes)))
    return pre, dec
