"""Top-k MoE with GShard/Switch-style capacity dispatch (TPU-native, dense
einsum dispatch — no data-dependent shapes, shardable under GSPMD).

Tokens are processed in fixed-size groups (``group_size``); each group builds a
[t, E, C] one-hot dispatch tensor (bounded < ~100 MB), experts run as a batched
[E, C, d] x [E, d, ff] einsum whose ff dim TP-shards on the model axis, and a
Switch-style load-balancing aux loss is returned. The same path serves both
training (t = sequence chunk) and batched decode (t = batch).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Dict[str, jnp.ndarray]

# §Perf iteration (granite train cell): when set to a mesh axis name, the
# dispatch/combine tensors get expert-dim sharding constraints so each EP
# shard computes ONLY its experts' slices (otherwise GSPMD all-gathers the
# [t, E, C] dispatch one-hot to every shard — 1.9 TiB/step at granite scale).
EP_CONSTRAINT = {"axis": None}


def set_ep_constraint(axis):
    EP_CONSTRAINT["axis"] = axis


def _ep(x, spec_fn):
    axis = EP_CONSTRAINT["axis"]
    if axis is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, spec_fn(axis))


def moe_init(key, cfg: ArchConfig) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "router": L.dense_init(ks[0], d, E, jnp.float32, scale=0.02),
        "w1": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) / np.sqrt(d)).astype(dt),
        "w3": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) / np.sqrt(d)).astype(dt),
        "w2": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
               / np.sqrt(2 * cfg.n_layers * ff)).astype(dt),
    }


def capacity(t: int, cfg: ArchConfig) -> int:
    c = int(np.ceil(t * cfg.experts_per_token / cfg.n_experts * cfg.capacity_factor))
    return max(4 * ((c + 3) // 4), 4)


def _moe_group(p: Params, x: jnp.ndarray, cfg: ArchConfig, cap: int):
    """x [t, d] -> (y [t, d], aux scalar). One dispatch group."""
    t, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    logits = x.astype(jnp.float32) @ p["router"]  # [t, E]
    probs = jax.nn.softmax(logits, axis=-1)
    wgt, widx = jax.lax.top_k(probs, k)  # [t, k]
    wgt = wgt / jnp.maximum(wgt.sum(-1, keepdims=True), 1e-9)

    # assignment mask [t, E] (top-k experts are distinct so sum over k is 0/1)
    assign = jax.nn.one_hot(widx, E, dtype=jnp.float32).sum(axis=1)  # [t, E]
    # position of each token within its expert's capacity buffer
    pos = jnp.cumsum(assign, axis=0) - assign  # [t, E]
    keep = (pos < cap) * assign
    # weighted expert coefficient per token
    wgt_e = (jax.nn.one_hot(widx, E, dtype=jnp.float32) * wgt[..., None]).sum(1)  # [t, E]

    from jax.sharding import PartitionSpec as P
    disp = keep[..., None] * jax.nn.one_hot(pos.astype(jnp.int32), cap,
                                            dtype=jnp.float32)  # [t, E, C]
    disp = _ep(disp, lambda ax: P(None, ax, None))
    disp_b = disp.astype(x.dtype)
    xe = jnp.einsum("tec,td->ecd", disp_b, x)  # [E, C, d]
    xe = _ep(xe, lambda ax: P(ax, None, None))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w1"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w3"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w2"])  # [E, C, d]
    ye = _ep(ye, lambda ax: P(ax, None, None))
    comb = (disp * (wgt_e * keep.astype(jnp.float32))[..., None]).astype(x.dtype)
    y = jnp.einsum("tec,ecd->td", comb, ye)

    # Switch load-balance aux: E * sum_e f_e * mean_prob_e
    frac = assign.mean(axis=0)
    mean_p = probs.mean(axis=0)
    aux = E * jnp.sum(frac * mean_p)
    return y, aux


def moe_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig,
              group_size: int = 2048) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, d] -> (y [B, S, d], aux). Groups scan over flattened tokens."""
    B, S, d = x.shape
    tokens = B * S
    g = min(group_size, tokens)
    n_groups = (tokens + g - 1) // g
    pad = n_groups * g - tokens
    flat = x.reshape(tokens, d)
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    flat = flat.reshape(n_groups, g, d)
    cap = capacity(g, cfg)

    def body(carry, xg):
        y, aux = _moe_group(p, xg, cfg, cap)
        return carry + aux, y

    aux_total, ys = jax.lax.scan(body, jnp.zeros((), jnp.float32), flat)
    y = ys.reshape(n_groups * g, d)[:tokens].reshape(B, S, d)
    return y, aux_total / n_groups
