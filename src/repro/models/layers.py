"""Core layers: RMSNorm, RoPE (standard / partial / M-RoPE), SwiGLU MLP,
embeddings. Pure functions over param pytrees; init mirrors apply.

Weights are stored in ``cfg.dtype`` (bf16 by default); math runs in fp32 where
numerically sensitive (norms, softmax, rope) and bf16 on matmul paths.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

Params = Dict[str, jnp.ndarray]


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rms_norm_init(d: int, dtype) -> Params:
    return {"w": ones((d,), jnp.float32)}


def rms_norm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["w"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE family
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, rotary_dim: Optional[int] = None):
    """Inverse frequencies for the rotary embedding (fp32)."""
    rd = rotary_dim or head_dim
    return 1.0 / (theta ** (np.arange(0, rd, 2, dtype=np.float32) / rd))


def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float,
                 rotary_dim: Optional[int] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions [..., S] -> cos/sin [..., S, rd//2] in fp32."""
    inv = jnp.asarray(rope_freqs(head_dim, theta, rotary_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions3: jnp.ndarray, head_dim: int, theta: float,
                  sections: Tuple[int, int, int]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Qwen2-VL M-RoPE. positions3 [3, B, S] (temporal, height, width).

    Each of the 3 position streams owns a contiguous slice of the head_dim/2
    frequency channels (sections sum to head_dim//2).
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = jnp.asarray(rope_freqs(head_dim, theta))  # [hd//2]
    ang = positions3.astype(jnp.float32)[..., None] * inv  # [3, B, S, hd//2]
    idx = jnp.asarray(
        np.repeat(np.arange(3), np.asarray(sections)), dtype=jnp.int32
    )  # [hd//2] -> which stream owns each channel
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -1), idx[None, None, :, None], axis=-1
    )[..., 0]  # [B, S, hd//2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x [B, S, H, hd]; cos/sin [B, S, rd//2] (broadcast over heads).

    Rotates the first ``2 * cos.shape[-1]`` channels (partial RoPE when the
    rotary dim is smaller than head_dim, as in GLM / DeepSeek indexer).
    """
    rd2 = cos.shape[-1]
    xf = x.astype(jnp.float32)
    rot, rest = xf[..., : 2 * rd2], xf[..., 2 * rd2:]
    x1, x2 = rot[..., :rd2], rot[..., rd2:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s, rest], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, d, ff, dt),
        "w3": dense_init(k2, d, ff, dt),
        "w2": dense_init(k3, ff, d, dt, scale=1.0 / np.sqrt(2 * cfg.n_layers * ff)),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return h @ p["w2"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ArchConfig) -> Params:
    dt = dtype_of(cfg)
    return {"w": dense_init(key, cfg.padded_vocab, cfg.d_model, dt, scale=0.02)}


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["w"], tokens, axis=0)


def lm_head_init(key, cfg: ArchConfig) -> Params:
    dt = dtype_of(cfg)
    return {"w": dense_init(key, cfg.d_model, cfg.padded_vocab, dt)}


def lm_head(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Logits over the PADDED vocab; pad rows masked to -inf."""
    logits = x @ p["w"]
    if cfg.padded_vocab != cfg.vocab_size:
        pad = cfg.padded_vocab - cfg.vocab_size
        mask = jnp.concatenate(
            [jnp.zeros((cfg.vocab_size,), logits.dtype),
             jnp.full((pad,), jnp.finfo(jnp.float32).min, logits.dtype)]
        )
        logits = logits + mask
    return logits


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy, fp32 logsumexp."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
