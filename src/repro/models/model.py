"""Model builder: init / forward / prefill / decode for all 10 assigned
architectures, with scan-over-layers (stacked params) so HLO size and compile
time stay flat in depth.

Families:
  dense | moe | audio | vlm : transformer (GQA attn + SwiGLU-or-MoE FFN)
  hybrid (zamba2)           : 13 x (6 Mamba2 + shared attn/MLP block) + 3 Mamba2
  ssm (xlstm)               : (mLSTM, sLSTM) pairs

Caches are dataclass-free pytrees (dicts) so they cross jit boundaries and
shard cleanly. ``length`` is a traced scalar.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X

Params = Dict

# §Perf (train cells): Megatron-style sequence-parallel residual stream.
# When set to a PartitionSpec, the residual activations between layers are
# constrained to it (sequence sharded over the model axis) — GSPMD then
# lowers the TP boundary as all-gather + reduce-scatter pairs instead of
# full fp32 all-reduces of [B, S, d]. Variant-gated from launch/dryrun.py.
SP_RESIDUAL = {"spec": None}


def set_sp_residual(spec):
    SP_RESIDUAL["spec"] = spec


def _sp(x):
    if SP_RESIDUAL["spec"] is None:
        return x
    return jax.lax.with_sharding_constraint(x, SP_RESIDUAL["spec"])


def _sp_gather(h):
    """Megatron-SP boundary: explicitly all-gather the normed activations
    entering the TP projections (bf16), instead of letting GSPMD pick an
    interior resharding point."""
    spec = SP_RESIDUAL["spec"]
    if spec is None:
        return h
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(h, P(spec[0], None, None))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _tf_layer_init(key, cfg: ArchConfig, tp: int) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "attn": A.attn_init(k1, cfg, tp),
        "attn_norm": L.rms_norm_init(cfg.d_model, None),
        "mlp_norm": L.rms_norm_init(cfg.d_model, None),
    }
    if cfg.n_experts:
        p["moe"] = M.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k2, cfg)
    return p


def _stacked(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg: ArchConfig, key, tp: int = 16) -> Params:
    ke, kl, kf, kh = jax.random.split(key, 4)
    params: Params = {
        "embed": L.embed_init(ke, cfg),
        "final_norm": L.rms_norm_init(cfg.d_model, None),
        "lm_head": L.lm_head_init(kh, cfg),
    }
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.shared_attn_every  # 13
        per = cfg.shared_attn_every                      # 6
        tail = cfg.n_layers - n_super * per              # 3
        kb, kt, ks = jax.random.split(kl, 3)
        body_keys = jax.random.split(kb, n_super * per).reshape(n_super, per, 2)
        params["body"] = jax.vmap(jax.vmap(lambda k: _mamba_layer_init(k, cfg)))(
            body_keys)
        params["tail"] = _stacked(lambda k: _mamba_layer_init(k, cfg), kt, tail)
        params["shared"] = _tf_layer_init(ks, cfg, tp)
    elif cfg.xlstm_pattern:
        nb = cfg.n_layers // len(cfg.xlstm_pattern)
        km, ks = jax.random.split(kl)
        params["mlstm"] = _stacked(
            lambda k: {"pre": L.rms_norm_init(cfg.d_model, None),
                       "blk": X.mlstm_init(k, cfg)}, km, nb)
        params["slstm"] = _stacked(
            lambda k: {"pre": L.rms_norm_init(cfg.d_model, None),
                       "blk": X.slstm_init(k, cfg)}, ks, nb)
    else:
        params["layers"] = _stacked(
            lambda k: _tf_layer_init(k, cfg, tp), kl, cfg.n_layers)
    return params


def _mamba_layer_init(key, cfg: ArchConfig) -> Params:
    return {"norm": L.rms_norm_init(cfg.d_model, None),
            "mamba": S.mamba_init(key, cfg)}


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def _rope_tables(cfg: ArchConfig, positions, positions3=None):
    if cfg.rope_style == "none":
        return None, None
    if cfg.rope_style == "mrope":
        assert positions3 is not None
        return L.mrope_cos_sin(positions3, cfg.hd, cfg.rope_theta,
                               cfg.mrope_sections)
    return L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)


def _attn_out(lp: Params, out: jnp.ndarray, cfg: ArchConfig, tp: int):
    """Apply dead-head mask then o-projection. out [B,S,Hp,hd] -> [B,S,d]."""
    hm = A.head_mask(cfg, tp)
    out = out * hm[None, None, :, None].astype(out.dtype)
    B, Sq, HP, hd = out.shape
    return out.reshape(B, Sq, HP * hd) @ lp["wo"]


def _tf_layer_full(lp, x, cos, sin, cfg, tp):
    """Full-sequence transformer layer; returns (x, aux, (k, v, q))."""
    h = _sp_gather(L.rms_norm(lp["attn_norm"], x, cfg.norm_eps))
    q, k, v = A.project_qkv(lp["attn"], h, cos, sin, cfg, tp)
    attn = A.attention_full(q, k, v, cfg, tp=tp)
    x = x + _attn_out(lp["attn"], attn, cfg, tp)
    x = _sp(x)
    h = _sp_gather(L.rms_norm(lp["mlp_norm"], x, cfg.norm_eps))
    if cfg.n_experts:
        y, aux = M.moe_apply(lp["moe"], h, cfg)
    else:
        y, aux = L.mlp(lp["mlp"], h), jnp.zeros((), jnp.float32)
    return x + y, aux, (k, v, q)


def _tf_layer_decode(lp, x, cos, sin, cfg, tp, kc, vc, length, sparse_fn=None,
                     sparse_params=None):
    """One-token transformer layer vs cache; returns (x, kc, vc, sp_new).

    A stateful sparse_fn may return (attn, new_sparse_params) — the
    incremental index cache of the prepare-memory stage lives there."""
    h = L.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
    q, k, v = A.project_qkv(lp["attn"], h, cos, sin, cfg, tp)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, length, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, length, 0, 0))
    sp_new = sparse_params
    if sparse_fn is not None:
        res = sparse_fn(q, kc, vc, length + 1, sparse_params, k_new=k)
        attn, sp_new = res if isinstance(res, tuple) else (res, sparse_params)
    else:
        attn = A.attention_decode(q, kc, vc, length + 1, cfg, tp=tp)
    x = x + _attn_out(lp["attn"], attn, cfg, tp)
    h = L.rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
    if cfg.n_experts:
        y, _ = M.moe_apply(lp["moe"], h, cfg)
    else:
        y = L.mlp(lp["mlp"], h)
    return x + y, kc, vc, sp_new


def _maybe_ckpt(fn, remat: bool):
    return jax.checkpoint(fn) if remat else fn


# ---------------------------------------------------------------------------
# forward (train / prefill full-sequence pass)
# ---------------------------------------------------------------------------


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,
    *,
    positions: Optional[jnp.ndarray] = None,
    positions3: Optional[jnp.ndarray] = None,
    img_embeds: Optional[jnp.ndarray] = None,
    collect_cache: bool = False,
    collect_q: bool = False,
    remat: bool = False,
    tp: int = 16,
):
    """tokens [B, S] -> (hidden [B,S,d], aux, caches-or-None).

    ``collect_q`` additionally stashes the per-layer query activations in
    ``caches["q"]`` ([L, B, S, Hp, hd]) — consumed by the hetero offload
    executor to seed the lookahead relevancy query after prefill. It is a
    prefill-only option; the cache dict handed to decode must not carry it.
    """
    B, Sq = tokens.shape
    x = L.embed(params["embed"], tokens)
    if img_embeds is not None:  # vlm stub: patch embeddings overwrite prefix
        x = jax.lax.dynamic_update_slice(x, img_embeds.astype(x.dtype), (0, 0, 0))
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    cos, sin = _rope_tables(cfg, positions, positions3)

    if cfg.family == "hybrid":
        return _hybrid_forward(params, cfg, x, cos, sin, collect_cache, remat, tp)
    if cfg.xlstm_pattern:
        return _xlstm_forward(params, cfg, x, collect_cache, remat)

    def layer_fn(carry, lp):
        x, aux = carry
        x, aux_l, kvq = _tf_layer_full(lp, x, cos, sin, cfg, tp)
        out = kvq if collect_q else kvq[:2]
        return (_sp(x), aux + aux_l), out if collect_cache else None

    (x, aux), kvs = jax.lax.scan(_maybe_ckpt(layer_fn, remat), (x, jnp.zeros((), jnp.float32)),
                                 params["layers"])
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    caches = None
    if collect_cache:
        caches = {"k": kvs[0], "v": kvs[1], "length": jnp.asarray(Sq, jnp.int32)}
        if collect_q:
            caches["q"] = kvs[2]
    return x, aux, caches


def _hybrid_forward(params, cfg, x, cos, sin, collect_cache, remat, tp):
    def super_fn(carry, lp):
        x, aux = carry
        body_lp, shared_kv_unused = lp, None

        def mamba_fn(x, mlp):
            h = L.rms_norm(mlp["norm"], x, cfg.norm_eps)
            y, st = S.mamba_forward(mlp["mamba"], h, cfg)
            return x + y, st if collect_cache else None

        x, states = jax.lax.scan(mamba_fn, x, body_lp)
        x, aux_l, kvq = _tf_layer_full(params["shared"], x, cos, sin, cfg, tp)
        return (x, aux + aux_l), (states, kvq[:2] if collect_cache else None)

    (x, aux), (body_states, shared_kvs) = jax.lax.scan(
        _maybe_ckpt(super_fn, remat), (x, jnp.zeros((), jnp.float32)), params["body"])

    def tail_fn(x, mlp):
        h = L.rms_norm(mlp["norm"], x, cfg.norm_eps)
        y, st = S.mamba_forward(mlp["mamba"], h, cfg)
        return x + y, st if collect_cache else None

    x, tail_states = jax.lax.scan(_maybe_ckpt(tail_fn, remat), x, params["tail"])
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    caches = None
    if collect_cache:
        caches = {
            "body_ssm": body_states[0], "body_conv": body_states[1],
            "tail_ssm": tail_states[0], "tail_conv": tail_states[1],
            "shared_k": shared_kvs[0], "shared_v": shared_kvs[1],
            "length": jnp.asarray(x.shape[1], jnp.int32),
        }
    return x, aux, caches


def _xlstm_forward(params, cfg, x, collect_cache, remat, states=None):
    nb = cfg.n_layers // 2

    def pair_fn(carry, lp):
        x = carry
        mlp, slp, st_in = lp
        y, mstate = X.mlstm_forward(
            mlp["blk"], L.rms_norm(mlp["pre"], x, cfg.norm_eps), cfg,
            None if st_in is None else st_in[0])
        x = x + y
        y, sstate = X.slstm_forward(
            slp["blk"], L.rms_norm(slp["pre"], x, cfg.norm_eps), cfg,
            None if st_in is None else st_in[1])
        x = x + y
        return x, (mstate, sstate) if collect_cache else None

    xs = (params["mlstm"], params["slstm"], states)
    if states is None:
        xs = (params["mlstm"], params["slstm"])
        fn = lambda c, lp: pair_fn(c, (lp[0], lp[1], None))
    else:
        fn = pair_fn
    x, new_states = jax.lax.scan(_maybe_ckpt(fn, remat), x, xs)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    caches = None
    if collect_cache:
        caches = {"states": new_states,
                  "length": jnp.asarray(x.shape[1], jnp.int32)}
    return x, jnp.zeros((), jnp.float32), caches


# ---------------------------------------------------------------------------
# losses / logits
# ---------------------------------------------------------------------------

MOE_AUX_COEF = 0.01


def train_loss(params, cfg: ArchConfig, batch: Dict, *, remat: bool = True,
               tp: int = 16) -> jnp.ndarray:
    x, aux, _ = forward(params, cfg, batch["tokens"],
                        positions3=batch.get("positions3"),
                        img_embeds=batch.get("img_embeds"),
                        remat=remat, tp=tp)
    logits = L.lm_head(params["lm_head"], x, cfg)
    loss = L.cross_entropy(logits, batch["labels"])
    return loss + MOE_AUX_COEF * aux


def last_logits(params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    return L.lm_head(params["lm_head"], x[:, -1:], cfg)[:, 0]


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def make_cache(cfg: ArchConfig, batch: int, max_len: int, tp: int = 16,
               dtype=None) -> Dict:
    dt = dtype or L.dtype_of(cfg)
    kv, hd = cfg.n_kv_heads, cfg.hd
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.shared_attn_every
        per = cfg.shared_attn_every
        tail = cfg.n_layers - n_super * per
        ssm, conv = S.mamba_state_init(cfg, batch)
        stack = lambda lead, t: jax.tree.map(
            lambda a: jnp.zeros(lead + a.shape, a.dtype), t)
        return {
            "body_ssm": stack((n_super, per), ssm),
            "body_conv": stack((n_super, per), conv),
            "tail_ssm": stack((tail,), ssm),
            "tail_conv": stack((tail,), conv),
            "shared_k": jnp.zeros((n_super, batch, max_len, kv, hd), dt),
            "shared_v": jnp.zeros((n_super, batch, max_len, kv, hd), dt),
            "length": jnp.zeros((), jnp.int32),
        }
    if cfg.xlstm_pattern:
        nb = cfg.n_layers // 2
        m = X.mlstm_state_init(cfg, batch)
        s = X.slstm_state_init(cfg, batch)
        stack = lambda t: tuple(jnp.zeros((nb,) + a.shape, a.dtype) for a in t)
        return {"states": (stack(m), stack(s)), "length": jnp.zeros((), jnp.int32)}
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, kv, hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, kv, hd), dt),
        "length": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: ArchConfig, tokens, *, max_len: Optional[int] = None,
            positions3=None, img_embeds=None, remat: bool = False, tp: int = 16):
    """Full prompt pass -> (last_logits [B, V], caches).

    Caches are padded to ``max_len`` (>= S) so decode can continue in place.
    """
    B, Sq = tokens.shape
    max_len = max_len or Sq
    x, _, caches = forward(params, cfg, tokens, positions3=positions3,
                           img_embeds=img_embeds, collect_cache=True,
                           remat=remat, tp=tp)
    if caches is not None and "k" in caches and max_len > Sq:
        pad = max_len - Sq
        caches["k"] = jnp.pad(caches["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        caches["v"] = jnp.pad(caches["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    if caches is not None and "shared_k" in caches and max_len > Sq:
        pad = max_len - Sq
        caches["shared_k"] = jnp.pad(
            caches["shared_k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        caches["shared_v"] = jnp.pad(
            caches["shared_v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return last_logits(params, cfg, x), caches


def decode_step(params, cfg: ArchConfig, token, caches, *, tp: int = 16,
                sparse_fn=None, sparse_params=None, sparse_stateful=False,
                positions3=None):
    """token [B] int32 + caches -> (logits [B, V], caches).

    ``sparse_fn(q, kcache, vcache, length, sparse_params_l) -> attn_out``
    lets the memory pipeline replace dense decode attention (DESIGN.md §2).
    ``sparse_params`` is a layer-stacked pytree scanned alongside the layers
    (per-layer indexer weights, e.g. the DSA lightning indexer). With
    ``sparse_stateful=True`` the sparse_fn returns (attn, new_params) —
    carrying an incremental index cache (prepare-once) — and decode_step
    returns (logits, caches, new_sparse_params).
    """
    B = token.shape[0]
    length = caches["length"]
    x = L.embed(params["embed"], token[:, None])
    positions = jnp.broadcast_to(length[None, None], (B, 1))
    if cfg.rope_style == "mrope" and positions3 is None:
        positions3 = jnp.broadcast_to(length[None, None, None], (3, B, 1))
    cos, sin = _rope_tables(cfg, positions, positions3)

    if cfg.family == "hybrid":
        x, caches = _hybrid_decode(params, cfg, x, cos, sin, caches, tp,
                                   sparse_fn, sparse_params)
    elif cfg.xlstm_pattern:
        # _xlstm_forward applies final_norm itself — return directly.
        x, _, new = _xlstm_forward(params, cfg, x, True, False,
                                   states=caches["states"])
        caches = dict(caches, states=new["states"], length=length + 1)
        return last_logits(params, cfg, x), caches
    else:
        stateful = sparse_stateful

        def layer_fn(x, lp_kv):
            lp, kc, vc, sp = lp_kv
            x, kc, vc, sp_new = _tf_layer_decode(lp, x, cos, sin, cfg, tp, kc,
                                                 vc, length, sparse_fn, sp)
            return x, ((kc, vc, sp_new) if stateful else (kc, vc))

        sp_stack = sparse_params
        if sp_stack is None:
            sp_stack = jnp.zeros((cfg.n_layers,), jnp.int32)  # dummy scan leaf
        x, ys = jax.lax.scan(
            layer_fn, x, (params["layers"], caches["k"], caches["v"], sp_stack))
        if stateful:
            k_new, v_new, sp_new = ys
        else:
            (k_new, v_new), sp_new = ys, sparse_params
        caches = dict(caches, k=k_new, v=v_new, length=length + 1)
        x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = last_logits(params, cfg, x)
        return (logits, caches, sp_new) if stateful else (logits, caches)

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return last_logits(params, cfg, x), caches


# ---------------------------------------------------------------------------
# Paged continuous-batching decode (serving): per-slot lengths + page pool
# ---------------------------------------------------------------------------


def make_page_pool(cfg: ArchConfig, n_slots: int, max_len: int, *,
                   page_size: int, total_pages: int, tp: int = 16,
                   dtype=None) -> Dict:
    """Device-side paged KV pool for transformer families.

    Physical page 0 is reserved as the zero/trash page: every unallocated
    page-table entry points at it, dead-slot writes are routed (zeroed) to
    it, and it must stay zero so pooled decode equals per-request decode.
    """
    dt = dtype or L.dtype_of(cfg)
    kv, hd = cfg.n_kv_heads, cfg.hd
    assert max_len % page_size == 0, (max_len, page_size)
    return {
        "k_pages": jnp.zeros((cfg.n_layers, total_pages, page_size, kv, hd), dt),
        "v_pages": jnp.zeros((cfg.n_layers, total_pages, page_size, kv, hd), dt),
        "page_table": jnp.zeros((n_slots, max_len // page_size), jnp.int32),
        "lengths": jnp.zeros((n_slots,), jnp.int32),
    }


def decode_step_paged(params, cfg: ArchConfig, token, pool, live, *,
                      tp: int = 16, sparse_fn=None, sparse_params=None,
                      positions3=None):
    """One decode step over the paged pool with PER-SLOT lengths.

    token [B] int32; pool from ``make_page_pool`` (lengths [B] must be
    pre-masked to 0 for dead slots); live [B] bool. Each slot gets its own
    RoPE position, its own cache-write offset, and its own attention mask —
    no slot pays for the longest sequence's watermark, and the sparse-method
    fallback cond sees the true max over live slots instead of a shared
    scalar. Returns (logits [B, V], pool') with pages updated in place and
    live lengths advanced by one.
    """
    from repro.kernels.page_pool import pool_gather, pool_scatter_token

    B = token.shape[0]
    lengths = pool["lengths"]
    table = pool["page_table"]
    live = live.astype(bool)
    x = L.embed(params["embed"], token[:, None])
    positions = lengths[:, None]                           # [B, 1] per-slot
    if cfg.rope_style == "mrope" and positions3 is None:
        positions3 = jnp.broadcast_to(lengths[None, :, None], (3, B, 1))
    cos, sin = _rope_tables(cfg, positions, positions3)

    def layer_fn(x, lp_kv):
        lp, kp, vp, sp = lp_kv
        h = L.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
        q, k, v = A.project_qkv(lp["attn"], h, cos, sin, cfg, tp)
        kp = pool_scatter_token(kp, table, lengths, k[:, 0], live)
        vp = pool_scatter_token(vp, table, lengths, v[:, 0], live)
        kc = pool_gather(kp, table)
        vc = pool_gather(vp, table)
        if sparse_fn is not None:
            res = sparse_fn(q, kc, vc, lengths + 1, sp, k_new=k)
            attn = res[0] if isinstance(res, tuple) else res
        else:
            attn = A.attention_decode(q, kc, vc, lengths + 1, cfg, tp=tp)
        x = x + _attn_out(lp["attn"], attn, cfg, tp)
        h = L.rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
        if cfg.n_experts:
            y, _ = M.moe_apply(lp["moe"], h, cfg)
        else:
            y = L.mlp(lp["mlp"], h)
        return x + y, (kp, vp)

    sp_stack = sparse_params
    if sp_stack is None:
        sp_stack = jnp.zeros((cfg.n_layers,), jnp.int32)   # dummy scan leaf
    x, (k_new, v_new) = jax.lax.scan(
        layer_fn, x, (params["layers"], pool["k_pages"], pool["v_pages"],
                      sp_stack))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    pool = dict(pool, k_pages=k_new, v_pages=v_new,
                lengths=lengths + live.astype(jnp.int32))
    return last_logits(params, cfg, x), pool


def decode_step_paged_presel(params, cfg: ArchConfig, token, pool, live,
                             pidx, mem, *, page_size: int, tp: int = 16,
                             page_attn=None):
    """Apply-phase decode over the paged pool with PRE-SELECTED pages.

    The hetero offload split (paper §5): prepare/relevancy/retrieve ran
    elsewhere (offload device, one step of lookahead) and handed back only
    page indices — this step is the compute-dense remainder that stays on
    the main device. ``pidx [L, B, n_sel]`` holds per-layer selected page
    ids in logical (per-slot) space, -1 = no selection.

    Semantics vs the inline sparse path:
      * the page currently being written (``lengths // page_size``) is
        always force-included so the newest tokens are never invisible to
        a stale selection (the paper's recency guarantee); a stale pick of
        the same page is deduplicated to avoid double-counted softmax mass,
      * indices outside the live region are dropped (stale-lookahead
        validity mask),
      * the paper's dynamic fallback stays a traced cond: outside
        [min_context, fallback_context] the step runs dense attention and
        ignores the selection entirely (single-device execution).

    ``page_attn`` overrides the selected-page attention implementation
    (same contract as ``ops.paged_decode_attention``: (q, kc, vc, pids,
    lengths, page_size=) -> (out, lse)). The main-mesh serving stack uses
    it to run ``distributed.topk.distributed_paged_sparse_decode`` when the
    main side is itself a mesh (LSE-merged sequence-parallel apply). With a
    ``page_attn`` installed, the DENSE fallback branch runs through the
    SAME seam — every view page selected is dense attention — so both
    sides of the traced cond are sequence-parallel and the step never
    collapses to a single device of the mesh.

    Returns (logits [B, V], pool', q_layers [L, B, Hp, hd], k_layers
    [L, B, KV, hd]) — the per-layer query/key of THIS step feed the next
    lookahead selection and the offload-side index update.
    """
    from repro.core import placement
    from repro.core.methods.dsa import strip_dead_heads, repad_dead_heads
    from repro.kernels import ops
    from repro.kernels.page_pool import pool_gather, pool_scatter_token

    B = token.shape[0]
    ps = page_size
    lengths = pool["lengths"]
    table = pool["page_table"]
    live = live.astype(bool)
    x = L.embed(params["embed"], token[:, None])
    positions = lengths[:, None]
    positions3 = None
    if cfg.rope_style == "mrope":
        positions3 = jnp.broadcast_to(lengths[None, :, None], (3, B, 1))
    cos, sin = _rope_tables(cfg, positions, positions3)

    lb = lengths + 1                       # context incl. this step's token
    cur_page = lengths // ps               # page receiving this step's write
    use_sparse = placement.traced_use_sparse(lb, mem)

    def layer_fn(x, lp_kv):
        lp, kp, vp, sel = lp_kv
        h = L.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
        q, k, v = A.project_qkv(lp["attn"], h, cos, sin, cfg, tp)
        kp = pool_scatter_token(kp, table, lengths, k[:, 0], live)
        vp = pool_scatter_token(vp, table, lengths, v[:, 0], live)
        kc = pool_gather(kp, table)
        vc = pool_gather(vp, table)

        def sparse(_):
            s = jnp.where(sel == cur_page[:, None], -1, sel)   # dedup recency
            s = jnp.where(s * ps < lb[:, None], s, -1)         # validity mask
            s_full = jnp.concatenate([s, cur_page[:, None]], axis=1)
            attn_fn = page_attn or ops.paged_decode_attention
            out, _ = attn_fn(
                strip_dead_heads(q, cfg), kc, vc, s_full.astype(jnp.int32),
                lb, page_size=ps)
            return repad_dead_heads(out, q, cfg)

        def dense(_):
            if page_attn is None:
                return A.attention_decode(q, kc, vc, lb, cfg, tp=tp)
            # distributed dense fallback: all view pages selected through
            # the same sequence-parallel seam (lb masks the live region)
            n_pages = kc.shape[1] // ps
            allp = jnp.broadcast_to(
                jnp.arange(n_pages, dtype=jnp.int32)[None], (B, n_pages))
            out, _ = page_attn(strip_dead_heads(q, cfg), kc, vc, allp, lb,
                               page_size=ps)
            return repad_dead_heads(out, q, cfg)

        attn = jax.lax.cond(use_sparse, sparse, dense, None)
        x = x + _attn_out(lp["attn"], attn, cfg, tp)
        h = L.rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
        if cfg.n_experts:
            y, _ = M.moe_apply(lp["moe"], h, cfg)
        else:
            y = L.mlp(lp["mlp"], h)
        return x + y, (kp, vp, q[:, 0], k[:, 0])

    x, (k_new, v_new, q_layers, k_layers) = jax.lax.scan(
        layer_fn, x, (params["layers"], pool["k_pages"], pool["v_pages"],
                      pidx))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    pool = dict(pool, k_pages=k_new, v_pages=v_new,
                lengths=lengths + live.astype(jnp.int32))
    return last_logits(params, cfg, x), pool, q_layers, k_layers


def extend_paged(params, cfg: ArchConfig, tokens, pool, n_valid, *,
                 tp: int = 16, collect_kq: bool = False, x_embeds=None,
                 emb_rows=None):
    """Chunked prefill: append a span of C tokens per slot to the paged pool.

    tokens [B, C] int32 (rows padded past ``n_valid[b]``); pool from
    ``make_page_pool``; n_valid [B] int32 (0 = slot not prefilling this
    step). Queries attend causally to the existing prefix plus the chunk.
    Returns (logits [B, V] at each row's last valid token, pool').

    With ``collect_kq`` two more outputs follow: k_span [L, B, C, KV, hd]
    (the span's raw new keys, unmasked past ``n_valid``; consumers mask)
    and q_last [L, B, Hp, hd] (the query at each row's last valid chunk
    token) — consumed by the hetero offload executor to keep its
    device-resident memory index coherent with the pool.
    ``decode_step_paged`` is the C=1 specialization of this, kept separate
    so the decode path can thread the sparse-method fallback.

    ``x_embeds [B, C, d]`` + ``emb_rows [B]`` feed rows with PRE-EMBEDDED
    context instead of token ids: the MaC retrieval service splices
    retrieved memory embeddings into a slot's context through the exact
    same chunked path its documents would take.
    """
    from repro.kernels.page_pool import pool_gather, pool_scatter_span

    B, C = tokens.shape
    lengths = pool["lengths"]
    table = pool["page_table"]
    x = L.embed(params["embed"], tokens)
    if x_embeds is not None:
        x = jnp.where(emb_rows[:, None, None], x_embeds.astype(x.dtype), x)
    positions = lengths[:, None] + jnp.arange(C)[None, :]  # [B, C]
    positions3 = None
    if cfg.rope_style == "mrope":
        positions3 = jnp.broadcast_to(positions[None], (3, B, C))
    cos, sin = _rope_tables(cfg, positions, positions3)

    def layer_fn(x, lp_kv):
        lp, kp, vp = lp_kv
        h = L.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
        q, k, v = A.project_qkv(lp["attn"], h, cos, sin, cfg, tp)
        kp = pool_scatter_span(kp, table, lengths, k, n_valid)
        vp = pool_scatter_span(vp, table, lengths, v, n_valid)
        kc = pool_gather(kp, table)
        vc = pool_gather(vp, table)
        attn = A.attention_decode_chunk(q, kc, vc, lengths, cfg, tp=tp)
        x = x + _attn_out(lp["attn"], attn, cfg, tp)
        h = L.rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
        if cfg.n_experts:
            y, _ = M.moe_apply(lp["moe"], h, cfg)
        else:
            y = L.mlp(lp["mlp"], h)
        return x + y, ((kp, vp, k, q) if collect_kq else (kp, vp))

    x, ys = jax.lax.scan(
        layer_fn, x, (params["layers"], pool["k_pages"], pool["v_pages"]))
    k_new, v_new = ys[0], ys[1]
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    last = jnp.clip(n_valid - 1, 0, C - 1)
    xg = jnp.take_along_axis(x, last[:, None, None], axis=1)   # [B, 1, d]
    logits = L.lm_head(params["lm_head"], xg, cfg)[:, 0]
    pool = dict(pool, k_pages=k_new, v_pages=v_new, lengths=lengths + n_valid)
    if not collect_kq:
        return logits, pool
    k_span, q_span = ys[2], ys[3]
    q_last = jnp.take_along_axis(
        q_span, last[None, :, None, None, None], axis=2)[:, :, 0]
    return logits, pool, k_span, q_last


def prefill_bucketed(params, cfg: ArchConfig, tokens, true_lens, *,
                     tp: int = 16, collect_q: bool = False):
    """Batched admission prefill over a length bucket.

    tokens [B, Sb] right-padded prompts; true_lens [B] real lengths.
    Returns (logits [B, V] at each row's last REAL token, k, v) where
    k/v [L, B, Sb, KV, hd] are zero-masked past ``true_lens`` so splicing
    them into the page pool leaves the dead region exactly zero (page-level
    relevancy scores must see the same zeros a per-request cache has).

    With ``collect_q`` a fourth output ``q_last [L, B, Hp, hd]`` carries each
    row's query activations at its last real token — the hetero offload
    executor seeds its lookahead relevancy query with it so the first decode
    step after admission selects pages with a real (one-step-stale) query.
    """
    B, Sb = tokens.shape
    x, _, caches = forward(params, cfg, tokens, collect_cache=True,
                           collect_q=collect_q, tp=tp)
    last = jnp.clip(true_lens - 1, 0, Sb - 1)
    xg = jnp.take_along_axis(x, last[:, None, None], axis=1)
    logits = L.lm_head(params["lm_head"], xg, cfg)[:, 0]
    mask = (jnp.arange(Sb)[None, :] < true_lens[:, None])      # [B, Sb]
    m = mask[None, :, :, None, None]
    k = caches["k"] * m.astype(caches["k"].dtype)
    v = caches["v"] * m.astype(caches["v"].dtype)
    if not collect_q:
        return logits, k, v
    q_last = jnp.take_along_axis(
        caches["q"], last[None, :, None, None, None], axis=2)[:, :, 0]
    return logits, k, v, q_last


def _hybrid_decode(params, cfg, x, cos, sin, caches, tp, sparse_fn,
                   sparse_params=None):
    length = caches["length"]

    def super_fn(x, lp):
        body_lp, ssm_st, conv_st, kc, vc = lp

        def mamba_fn(x, mlp_st):
            mlp, sst, cst = mlp_st
            h = L.rms_norm(mlp["norm"], x, cfg.norm_eps)
            y, (sst, cst) = S.mamba_decode(mlp["mamba"], h, cfg, (sst, cst))
            return x + y, (sst, cst)

        x, (ssm_new, conv_new) = jax.lax.scan(
            mamba_fn, x, (body_lp, ssm_st, conv_st))
        x, kc, vc, _ = _tf_layer_decode(params["shared"], x, cos, sin, cfg,
                                        tp, kc, vc, length, sparse_fn,
                                        sparse_params)
        return x, (ssm_new, conv_new, kc, vc)

    x, (bs, bc, sk, sv) = jax.lax.scan(
        super_fn, x,
        (params["body"], caches["body_ssm"], caches["body_conv"],
         caches["shared_k"], caches["shared_v"]))

    def tail_fn(x, mlp_st):
        mlp, sst, cst = mlp_st
        h = L.rms_norm(mlp["norm"], x, cfg.norm_eps)
        y, (sst, cst) = S.mamba_decode(mlp["mamba"], h, cfg, (sst, cst))
        return x + y, (sst, cst)

    x, (ts, tc) = jax.lax.scan(
        tail_fn, x, (params["tail"], caches["tail_ssm"], caches["tail_conv"]))
    caches = dict(caches, body_ssm=bs, body_conv=bc, tail_ssm=ts, tail_conv=tc,
                  shared_k=sk, shared_v=sv, length=length + 1)
    return x, caches
