"""xLSTM blocks: mLSTM (matrix memory, exp gating) and sLSTM (scalar memory,
block-diagonal recurrence). [arXiv:2405.04517]

Both use the stabilized recurrent formulation (running max m_t) and execute as
a lax.scan over time — exact, O(1)-state decode for free. (A chunked-parallel
mLSTM would speed up training; this arch is attention-free so it is outside
the paper's hillclimb targets, see DESIGN.md §4.)

States:
  mLSTM: (C [B,H,dk,dv], n [B,H,dk], m [B,H])
  sLSTM: (c [B,H,dh], n [B,H,dh], h [B,H,dh], m [B,H,dh])
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    di = 2 * d  # expansion 2
    H = cfg.n_heads
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up": L.dense_init(ks[0], d, 2 * di, dt),  # (x_inner, z gate)
        "wq": L.dense_init(ks[1], di, di, dt),
        "wk": L.dense_init(ks[2], di, di, dt),
        "wv": L.dense_init(ks[3], di, di, dt),
        "wi": L.dense_init(ks[4], di, H, jnp.float32, scale=0.02),
        "wf": L.dense_init(ks[5], di, H, jnp.float32, scale=0.02),
        "bi": L.zeros((H,), jnp.float32),
        "bf": L.ones((H,), jnp.float32) * 3.0,  # forget-dominant init
        "norm": L.ones((di,), jnp.float32),
        "down": L.dense_init(ks[6], di, d, dt,
                             scale=1.0 / np.sqrt(2 * cfg.n_layers * di)),
    }


def mlstm_state_init(cfg: ArchConfig, batch: int):
    H = cfg.n_heads
    dh = 2 * cfg.d_model // H
    return (
        jnp.zeros((batch, H, dh, dh), jnp.float32),
        jnp.zeros((batch, H, dh), jnp.float32),
        jnp.full((batch, H), -1e30, jnp.float32),
    )


def mlstm_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig, state=None):
    """x [B,S,d] -> (y [B,S,d], state)."""
    B, S, d = x.shape
    H = cfg.n_heads
    di = 2 * d
    dh = di // H
    up = x @ p["up"]
    inner, z = up[..., :di], up[..., di:]
    q = (inner @ p["wq"]).reshape(B, S, H, dh).astype(jnp.float32) / np.sqrt(dh)
    k = (inner @ p["wk"]).reshape(B, S, H, dh).astype(jnp.float32) / np.sqrt(dh)
    v = (inner @ p["wv"]).reshape(B, S, H, dh).astype(jnp.float32)
    logi = inner.astype(jnp.float32) @ p["wi"] + p["bi"]  # [B,S,H]
    logf = jax.nn.log_sigmoid(inner.astype(jnp.float32) @ p["wf"] + p["bf"])

    if state is None:
        state = mlstm_state_init(cfg, B)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        m_new = jnp.maximum(ft + m, it)                     # [B,H]
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        C = f_s[..., None, None] * C + i_s[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])            # [B,H,dk,dv]
        n = f_s[..., None] * n + i_s[..., None] * kt
        num = jnp.einsum("bhkv,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    to_t = lambda a: jnp.moveaxis(a, 1, 0)
    state, hs = jax.lax.scan(step, state,
                             (to_t(q), to_t(k), to_t(v), to_t(logi), to_t(logf)))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, di)            # [B,S,di]
    h = L.rms_norm({"w": p["norm"]}, h.astype(x.dtype), cfg.norm_eps)
    h = h * jax.nn.silu(z)
    return h @ p["down"], state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 3)
    w = jax.random.normal(ks[0], (4, d, d), jnp.float32) / np.sqrt(d)
    r = jax.random.normal(ks[1], (4, H, dh, dh), jnp.float32) / np.sqrt(dh)
    return {
        "w": w.astype(dt),                       # input weights (i, f, z, o)
        "r": r.astype(jnp.float32),              # block-diag recurrent weights
        "b": L.zeros((4, d), jnp.float32).at[1].set(3.0),  # forget bias
        "norm": L.ones((d,), jnp.float32),
        "out": L.dense_init(ks[2], d, d, dt,
                            scale=1.0 / np.sqrt(2 * cfg.n_layers * d)),
    }


def slstm_state_init(cfg: ArchConfig, batch: int):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return (z, z + 1e-6, z, z - 10.0)  # c, n, h, m


def slstm_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig, state=None):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    gates_in = jnp.einsum("bsd,gde->gbse", x, p["w"].astype(x.dtype)) + 0.0
    gates_in = gates_in.astype(jnp.float32) + p["b"][:, None, None, :]
    gates_in = gates_in.reshape(4, B, S, H, dh)
    if state is None:
        state = slstm_state_init(cfg, B)

    def step(carry, g):
        c, n, h, m = carry
        rec = jnp.einsum("ghkl,bhk->gbhl", p["r"], h)  # [4,B,H,dh]
        gi, gf, gz, go = g + rec
        m_new = jnp.maximum(jax.nn.log_sigmoid(gf) + m, gi)
        i_s = jnp.exp(gi - m_new)
        f_s = jnp.exp(jax.nn.log_sigmoid(gf) + m - m_new)
        c = f_s * c + i_s * jnp.tanh(gz)
        n = f_s * n + i_s
        h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(gates_in, 2, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d)
    h = L.rms_norm({"w": p["norm"]}, h.astype(x.dtype), cfg.norm_eps)
    return h @ p["out"], state
