"""GQA attention with qk-norm / qkv-bias / sliding-window / RoPE variants.

Two execution paths:
  * ``attention_full``   — chunked online-softmax ("XLA-flash") for train/prefill;
    memory is O(S * chunk), never materializes the S x S score matrix.
  * ``attention_decode`` — one query token vs a KV cache (dense fallback path;
    the memory-processing pipeline replaces this with sparse retrieval).

TP note (DESIGN.md §5): query heads are padded to a multiple of the model axis
with *dead heads* — their q/k/v rows and o-proj columns are zero-initialized
and an explicit static head mask keeps their gradients identically zero.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Dict[str, jnp.ndarray]

NEG_INF = -1e30


def attn_init(key, cfg: ArchConfig, tp: int = 16) -> Params:
    d, hd, kv = cfg.d_model, cfg.hd, cfg.n_kv_heads
    hp = cfg.padded_heads(tp)
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": L.dense_init(ks[0], d, hp * hd, dt),
        "wk": L.dense_init(ks[1], d, kv * hd, dt),
        "wv": L.dense_init(ks[2], d, kv * hd, dt),
        "wo": L.dense_init(ks[3], hp * hd, d, dt, scale=1.0 / np.sqrt(2 * cfg.n_layers * hp * hd)),
    }
    if hp != cfg.n_heads:  # zero the dead-head slices
        wq = p["wq"].reshape(d, hp, hd).at[:, cfg.n_heads:, :].set(0.0)
        wo = p["wo"].reshape(hp, hd, d).at[cfg.n_heads:, :, :].set(0.0)
        p["wq"] = wq.reshape(d, hp * hd)
        p["wo"] = wo.reshape(hp * hd, d)
    if cfg.qkv_bias:
        p["bq"] = L.zeros((hp * hd,), dt)
        p["bk"] = L.zeros((kv * hd,), dt)
        p["bv"] = L.zeros((kv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = L.ones((hd,), jnp.float32)
        p["k_norm"] = L.ones((hd,), jnp.float32)
    return p


def head_mask(cfg: ArchConfig, tp: int = 16) -> jnp.ndarray:
    hp = cfg.padded_heads(tp)
    return jnp.asarray((np.arange(hp) < cfg.n_heads).astype(np.float32))


def head_to_kv(cfg: ArchConfig, tp: int = 16) -> np.ndarray:
    """Static map padded-query-head -> kv head (dead heads map to kv 0)."""
    hp, h, kv = cfg.padded_heads(tp), cfg.n_heads, cfg.n_kv_heads
    g = max(h // kv, 1)
    m = np.minimum(np.arange(hp) // g, kv - 1)
    m[h:] = 0
    return m.astype(np.int32)


def project_qkv(
    p: Params,
    x: jnp.ndarray,
    cos: jnp.ndarray,
    sin: jnp.ndarray,
    cfg: ArchConfig,
    tp: int = 16,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x [B, S, d] -> q [B, S, Hp, hd], k/v [B, S, KV, hd] (rope applied)."""
    B, S, _ = x.shape
    hd, kv = cfg.hd, cfg.n_kv_heads
    hp = cfg.padded_heads(tp)
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, hp, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = L.rms_norm({"w": p["q_norm"]}, q, cfg.norm_eps)
        k = L.rms_norm({"w": p["k_norm"]}, k, cfg.norm_eps)
    if cfg.rope_style != "none":
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    return q, k, v


def expand_kv(kv_arr: jnp.ndarray, cfg: ArchConfig, tp: int = 16) -> jnp.ndarray:
    """[..., KV, hd] -> [..., Hp, hd] by group broadcast (or gather)."""
    hp, kv = cfg.padded_heads(tp), cfg.n_kv_heads
    if hp % kv == 0:
        reps = hp // kv
        return jnp.repeat(kv_arr, reps, axis=-2)
    return jnp.take(kv_arr, jnp.asarray(head_to_kv(cfg, tp)), axis=-2)


# ---------------------------------------------------------------------------
# Full-sequence causal attention (train / prefill): chunked online softmax.
# ---------------------------------------------------------------------------


def attention_full(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: ArchConfig,
    *,
    q_chunk: int = 256,
    window: Optional[int] = None,
    tp: int = 16,
) -> jnp.ndarray:
    """Causal attention; q [B,S,Hp,hd], k/v [B,S,KV,hd] -> [B,S,Hp,hd].

    Scans QUERY chunks: each step materializes only a transient
    [B, H, q_chunk, S] score tile (no running-softmax carry — a carried
    (m, l, acc) formulation makes XLA hoist S^2-sized loop invariants into
    the while carry; see EXPERIMENTS.md §Perf iteration log).
    """
    B, S, HP, hd = q.shape
    window = window if window is not None else (cfg.sliding_window or None)
    kexp = expand_kv(k, cfg, tp).astype(jnp.float32)  # [B, S, Hp, hd]
    vexp = expand_kv(v, cfg, tp).astype(jnp.float32)
    bq = min(q_chunk, S)
    pad = (-S) % bq
    scale = 1.0 / np.sqrt(hd)
    q32 = q.astype(jnp.float32) * scale
    if pad:
        q32 = jnp.pad(q32, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (S + pad) // bq
    q32 = jnp.moveaxis(q32.reshape(B, nq, bq, HP, hd), 1, 0)
    kpos = jnp.arange(S)

    def step(i, qc):
        qpos = i * bq + jnp.arange(bq)
        sc = jnp.einsum("bqhd,bkhd->bhqk", qc, kexp)  # [B,Hp,bq,S]
        mask = qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        sc = jnp.where(mask[None, None], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vexp)  # [B,bq,Hp,hd]

    outs = jax.lax.map(lambda args: step(*args), (jnp.arange(nq), q32))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S + pad, HP, hd)
    return out[:, :S].astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (dense fallback): 1 query token vs KV cache.
# ---------------------------------------------------------------------------


def attention_decode(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    length: jnp.ndarray,
    cfg: ArchConfig,
    *,
    window: Optional[int] = None,
    tp: int = 16,
) -> jnp.ndarray:
    """q [B,1,Hp,hd]; caches [B,Smax,KV,hd]; length [] or [B] -> [B,1,Hp,hd]."""
    B, Smax = k_cache.shape[0], k_cache.shape[1]
    hd = q.shape[-1]
    window = window if window is not None else (cfg.sliding_window or None)
    kexp = expand_kv(k_cache, cfg, tp)
    vexp = expand_kv(v_cache, cfg, tp)
    scale = 1.0 / np.sqrt(hd)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                    kexp.astype(jnp.float32))  # [B,Hp,1,Smax]
    pos = jnp.arange(Smax)
    length = jnp.asarray(length)
    lb = length if length.ndim else length[None].repeat(B)
    mask = pos[None, :] < lb[:, None]  # [B, Smax]
    if window:
        mask &= pos[None, :] >= (lb[:, None] - window)
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vexp.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_decode_chunk(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    start: jnp.ndarray,
    cfg: ArchConfig,
    *,
    window: Optional[int] = None,
    tp: int = 16,
) -> jnp.ndarray:
    """Chunked-prefill attention: C new query tokens vs an in-place cache.

    q [B,C,Hp,hd]; caches [B,Smax,KV,hd] (the C new keys are already written
    at positions start[b]..start[b]+C-1); start [B] -> [B,C,Hp,hd]. Query i
    of row b sits at position start[b]+i and attends causally to everything
    at or before it. Padding queries (beyond a row's real span) just produce
    garbage rows the caller ignores.
    """
    B, C = q.shape[:2]
    Smax = k_cache.shape[1]
    hd = q.shape[-1]
    window = window if window is not None else (cfg.sliding_window or None)
    kexp = expand_kv(k_cache, cfg, tp)
    vexp = expand_kv(v_cache, cfg, tp)
    scale = 1.0 / np.sqrt(hd)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                    kexp.astype(jnp.float32))          # [B,Hp,C,Smax]
    kpos = jnp.arange(Smax)
    qpos = start[:, None] + jnp.arange(C)[None, :]     # [B, C]
    mask = kpos[None, None, :] <= qpos[:, :, None]     # [B, C, Smax]
    if window:
        mask &= kpos[None, None, :] > (qpos[:, :, None] - window)
    sc = jnp.where(mask[:, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vexp.astype(jnp.float32))
    return out.astype(q.dtype)
