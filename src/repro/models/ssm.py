"""Mamba2 (SSD) block — chunked matmul formulation for train/prefill, O(1)
recurrent state update for decode. Single B/C group (ssm_groups == 1).

TP layout: the z/x/B/C/dt projections are SEPARATE weights (not one fused
in_proj) so each output can shard independently — z/x/dt shard on d_inner/H
over the model axis (all downstream per-channel ops stay local), while the
small B/C/state tensors replicate. The fused-projection variant would slice a
sharded concatenated axis and force resharding collectives.

Memory discipline: the intra-chunk decay tensor exp(cum_i - cum_j) is formed
per (chunk, head-group) only — lax.scan over chunks x lax.map over head
groups bounds the live intermediate to [B, cs, cs, hg] (~MBs). The
numerically-safe *difference* form (exp argument <= 0) is kept — the
factorized exp(cum_i)*exp(-cum_j) variant overflows fp32 for fast-decaying
heads even at init.

State: ssm [B, H, P, N]; conv (x [B, di, K-1], B [B, N, K-1], C [B, N, K-1]).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Dict

HEAD_GROUP = 16  # heads per intra-chunk block


def mamba_init(key, cfg: ArchConfig) -> Params:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    K = cfg.ssm_conv
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 9)
    u = jax.random.uniform(ks[0], (H,), jnp.float32)
    dt0 = jnp.exp(u * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    conv = lambda k, c: (jax.random.normal(k, (c, K), jnp.float32)
                         / np.sqrt(K)).astype(dt)
    return {
        "w_z": L.dense_init(ks[1], d, di, dt),
        "w_x": L.dense_init(ks[2], d, di, dt),
        "w_B": L.dense_init(ks[3], d, N, dt),
        "w_C": L.dense_init(ks[4], d, N, dt),
        "w_dt": L.dense_init(ks[5], d, H, dt),
        "conv_x": conv(ks[6], di),
        "conv_B": conv(ks[7], N),
        "conv_C": conv(ks[8], N),
        "conv_bx": L.zeros((di,), dt),
        "conv_bB": L.zeros((N,), dt),
        "conv_bC": L.zeros((N,), dt),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": L.ones((H,), jnp.float32),
        "dt_bias": dt0 + jnp.log(-jnp.expm1(-dt0)),  # inverse softplus
        "norm": L.ones((di,), jnp.float32),
        "out_proj": L.dense_init(ks[0], di, d, dt,
                                 scale=1.0 / np.sqrt(2 * cfg.n_layers * di)),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray = None):
    """Depthwise causal conv over S. x [B, S, C]; w [C, K]; state [B,C,K-1]."""
    B, S, C = x.shape
    K = w.shape[1]
    if state is None:
        padded = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        padded = jnp.concatenate([jnp.moveaxis(state, 1, 2), x], axis=1)
    out = sum(padded[:, k: k + S, :] * w[:, k] for k in range(K))
    new_state = jnp.moveaxis(padded[:, -(K - 1):, :], 1, 2) if K > 1 else None
    return jax.nn.silu(out + b), new_state


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, w: jnp.ndarray, eps: float):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * w


def _project(p: Params, x: jnp.ndarray, cfg: ArchConfig, conv_state=None):
    """Shared prologue: projections + causal convs + dt/A prep."""
    cs_x, cs_B, cs_C = conv_state if conv_state else (None, None, None)
    z = x @ p["w_z"]
    xr, ns_x = _causal_conv(x @ p["w_x"], p["conv_x"], p["conv_bx"], cs_x)
    Br, ns_B = _causal_conv(x @ p["w_B"], p["conv_B"], p["conv_bB"], cs_B)
    Cr, ns_C = _causal_conv(x @ p["w_C"], p["conv_C"], p["conv_bC"], cs_C)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    return z, xr, Br, Cr, dt, A, (ns_x, ns_B, ns_C)


def _intra_chunk(scores, cum, x_c, mask):
    """One chunk's intra term, head-grouped.

    scores [B,i,j]; cum [B,cs,H]; x_c [B,cs,H,P] -> [B,cs,H,P]."""
    B, cs, H = cum.shape
    hg = min(HEAD_GROUP, H)
    n_hg = (H + hg - 1) // hg
    pad = n_hg * hg - H
    if pad:
        cum = jnp.pad(cum, ((0, 0), (0, 0), (0, pad)))
        x_c = jnp.pad(x_c, ((0, 0), (0, 0), (0, pad), (0, 0)))
    cum_g = jnp.moveaxis(cum.reshape(B, cs, n_hg, hg), 2, 0)
    x_g = jnp.moveaxis(x_c.reshape(B, cs, n_hg, hg, -1), 2, 0)

    def one_group(args):
        cg, xg = args
        diff = cg[:, :, None, :] - cg[:, None, :, :]          # [B,i,j,hg]
        Lm = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        return jnp.einsum("bij,bijh,bjhp->bihp", scores, Lm, xg)

    y = jax.lax.map(one_group, (cum_g, x_g))                   # [n_hg,B,cs,hg,P]
    y = jnp.moveaxis(y, 0, 2).reshape(B, cs, n_hg * hg, -1)
    return y[:, :, :H]


def mamba_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                  init_state: Tuple = None):
    """x [B, S, d] -> (y [B, S, d], (ssm_state, conv_states)). Chunked SSD."""
    B, S, d = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    cs = min(cfg.ssm_chunk, S)
    assert S % cs == 0, (S, cs)
    nc = S // cs

    conv_in = None if init_state is None else init_state[1]
    z, xr, Br, Cr, dt, A, conv_state = _project(p, x, cfg, conv_in)
    xs = xr.reshape(B, S, H, P).astype(jnp.float32)
    Bm = Br.astype(jnp.float32)
    Cm = Cr.astype(jnp.float32)
    dA = dt * A
    xdt = xs * dt[..., None]

    mask = jnp.tril(jnp.ones((cs, cs), bool))
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state[0].astype(jnp.float32))

    def chunk_body(state, inp):
        dA_c, x_c, B_c, C_c, xs_c = inp
        cum = jnp.cumsum(dA_c, axis=1)                       # [B, cs, H]
        scores = jnp.einsum("bin,bjn->bij", C_c, B_c)
        y = _intra_chunk(scores, cum, x_c, mask)
        y = y + jnp.einsum("bin,bih,bhpn->bihp", C_c, jnp.exp(cum), state)
        y = y + xs_c * p["D"][None, None, :, None]
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)
        new_state = (state * jnp.exp(cum[:, -1, :])[:, :, None, None]
                     + jnp.einsum("bjn,bjh,bjhp->bhpn", B_c, decay_to_end, x_c))
        return new_state, y

    def to_chunks(a):
        return jnp.moveaxis(a.reshape((B, nc, cs) + a.shape[2:]), 1, 0)

    final_state, ys = jax.lax.scan(
        chunk_body, s0,
        (to_chunks(dA), to_chunks(xdt), to_chunks(Bm), to_chunks(Cm),
         to_chunks(xs)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = y.astype(x.dtype) @ p["out_proj"]
    return out, (final_state, conv_state)


def mamba_decode(p: Params, x: jnp.ndarray, cfg: ArchConfig, state: Tuple):
    """Single-token step. x [B, 1, d]; state (ssm, conv_states)."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    ssm_state, conv_in = state
    z, xr, Br, Cr, dt, A, conv_state = _project(p, x, cfg, conv_in)
    xs = xr[:, 0].reshape(B, H, P).astype(jnp.float32)
    Bm = Br[:, 0].astype(jnp.float32)
    Cm = Cr[:, 0].astype(jnp.float32)
    dt = dt[:, 0]                                            # [B, H]
    decay = jnp.exp(dt * A)
    ssm_state = (ssm_state.astype(jnp.float32) * decay[..., None, None]
                 + jnp.einsum("bhp,bn->bhpn", xs * dt[..., None], Bm))
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, Cm) + xs * p["D"][None, :, None]
    y = y.reshape(B, 1, di)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = y.astype(x.dtype) @ p["out_proj"]
    return out, (ssm_state, conv_state)


def mamba_state_init(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    K = cfg.ssm_conv
    return (
        jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        (
            jnp.zeros((batch, cfg.d_inner, K - 1), dtype),
            jnp.zeros((batch, cfg.ssm_state, K - 1), dtype),
            jnp.zeros((batch, cfg.ssm_state, K - 1), dtype),
        ),
    )
