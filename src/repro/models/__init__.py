from repro.models.model import (
    init_params,
    forward,
    train_loss,
    prefill,
    decode_step,
    make_cache,
    last_logits,
)
