"""AdamW with cosine schedule, global-norm clipping, and fp32 master moments
over bf16 params (mixed-precision training without optax)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    residual: Any  # gradient-compression error feedback (or None)


def init_opt_state(params, compress: str = "none") -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    residual = (jax.tree.map(zeros, params) if compress == "int8" else None)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        residual=residual,
    )


def schedule(oc: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - oc.warmup_steps) /
                    jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (oc.min_lr_frac + (1 - oc.min_lr_frac) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(grads, state: OptState, params, oc: OptConfig):
    """Returns (new_params, new_state, stats)."""
    step = state.step + 1
    lr = schedule(oc, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / (gnorm + 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = oc.b1 * m + (1 - oc.b1) * g
        v = oc.b2 * v + (1 - oc.b2) * g * g
        mh = m / (1 - oc.b1 ** step.astype(jnp.float32))
        vh = v / (1 - oc.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + oc.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tree.unflatten([o[0] for o in out])
    new_m = tree.unflatten([o[1] for o in out])
    new_v = tree.unflatten([o[2] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_p, OptState(step, new_m, new_v, state.residual), stats
