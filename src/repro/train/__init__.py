from repro.train.optimizer import OptConfig, OptState, init_opt_state, adamw_update
from repro.train.trainer import TrainConfig, Trainer, make_train_step
