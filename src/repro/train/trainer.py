"""Training loop substrate: jit'd train_step with remat, microbatch gradient
accumulation, optional compressed cross-pod gradient sync (error feedback),
and checkpoint/restart integration.

Fault tolerance: the Trainer saves every ``ckpt_every`` steps (atomic), tags
the data-stream position in the manifest, restores the latest checkpoint on
construction, and exposes ``emergency_save`` for the launcher's signal
handler (straggler/preemption path — distributed/elastic.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ArchConfig
from repro.distributed import checkpoint as ckpt
from repro.distributed.collectives import compressed_grads_with_feedback
from repro.models import model as M
from repro.train.optimizer import OptConfig, OptState, adamw_update, init_opt_state


@dataclasses.dataclass
class TrainConfig:
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)
    accum: int = 1                 # microbatch gradient accumulation
    compress: str = "none"         # none | bf16 | int8 (cross-pod sync)
    remat: bool = True
    ckpt_dir: str = ""
    ckpt_every: int = 100
    tp: int = 16


def make_train_step(cfg: ArchConfig, tc: TrainConfig,
                    mesh: Optional[Mesh] = None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With accum > 1 the batch leading dim must be [accum, mb, S]; gradients
    average over microbatches inside a scan (bounds activation memory to one
    microbatch at a time).
    """

    def loss_fn(p, b):
        return M.train_loss(p, cfg, b, remat=tc.remat, tp=tc.tp)

    def grads_of(params, batch):
        if tc.accum <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + l / tc.accum,
                    jax.tree.map(lambda a, b: a + b / tc.accum, g_acc, g)), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zero), batch)
        return loss, grads

    pod_sync = (tc.compress != "none" and mesh is not None
                and "pod" in mesh.shape and mesh.shape["pod"] > 1)

    def step_fn(params, opt_state: OptState, batch):
        loss, grads = grads_of(params, batch)
        residual = opt_state.residual
        if pod_sync:
            # explicit compressed cross-pod all-reduce (bf16/int8 wire) with
            # error feedback; within-pod reduction stays implicit (GSPMD).
            grads, residual = compressed_grads_with_feedback(
                grads, residual, tc.compress)
            if tc.compress == "bf16":
                grads = jax.tree.map(
                    lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
        new_params, new_state, stats = adamw_update(
            grads, opt_state._replace(residual=residual), params, tc.opt)
        stats["loss"] = loss
        return new_params, new_state, stats

    return jax.jit(step_fn, donate_argnums=(0, 1))


class Trainer:
    def __init__(self, cfg: ArchConfig, tc: TrainConfig, params,
                 mesh: Optional[Mesh] = None):
        self.cfg, self.tc = cfg, tc
        self.params = params
        self.opt_state = init_opt_state(params, tc.compress)
        self.step_fn = make_train_step(cfg, tc, mesh)
        self.step = 0
        self.mesh = mesh
        if tc.ckpt_dir:
            last = ckpt.latest_step(tc.ckpt_dir)
            if last is not None:
                self.restore(last)

    def train_step(self, batch) -> Dict[str, float]:
        self.params, self.opt_state, stats = self.step_fn(
            self.params, self.opt_state, batch)
        self.step += 1
        if self.tc.ckpt_dir and self.step % self.tc.ckpt_every == 0:
            self.save()
        return {k: float(v) for k, v in stats.items()}

    def save(self):
        ckpt.save(self.tc.ckpt_dir, self.step,
                  {"params": self.params, "m": self.opt_state.m,
                   "v": self.opt_state.v},
                  extra={"opt_step": int(self.opt_state.step)})

    def emergency_save(self):
        """Preemption/straggler-eviction hook (atomic, safe to call anytime)."""
        if self.tc.ckpt_dir:
            self.save()

    def restore(self, step: int):
        like = {"params": self.params, "m": self.opt_state.m,
                "v": self.opt_state.v}
        tree = ckpt.restore(self.tc.ckpt_dir, step, like)
        self.params = tree["params"]
        man = ckpt.read_manifest(self.tc.ckpt_dir, step)
        self.opt_state = self.opt_state._replace(
            m=tree["m"], v=tree["v"],
            step=jnp.asarray(man["extra"].get("opt_step", step), jnp.int32))
        self.step = step
