from repro.data.pipeline import TokenStream, build_corpus, sample_queries, pack_documents
