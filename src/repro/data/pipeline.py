"""Deterministic synthetic data pipeline.

* ``TokenStream`` — seeded Zipf-ish token sequences with local structure
  (Markov bigram mixing) so losses decrease measurably during smoke training;
  per-host sharding by (host_index, num_hosts); packing to fixed seq_len.
* ``build_corpus`` — synthetic retrieval corpus for RAG (doc-term frequency
  matrix, doc lengths, IDF, doc token payloads, optional doc embeddings),
  matching the *computational* shape of the paper's Wikipedia BM25 setup.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    host_index: int = 0
    num_hosts: int = 1
    zipf_a: float = 1.2

    def __post_init__(self):
        self._rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.host_index]))
        v = self.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = ranks ** (-self.zipf_a)
        self._probs /= self._probs.sum()
        # bigram structure: token t prefers (t*7+3) % v next — learnable signal
        self._next = (np.arange(v) * 7 + 3) % v

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, np.ndarray]:
        B, S, v = self.batch_size, self.seq_len, self.vocab_size
        base = self._rng.choice(v, size=(B, S), p=self._probs)
        toks = base.copy()
        # 60% of positions follow the deterministic bigram of the previous tok
        follow = self._rng.random((B, S)) < 0.6
        toks[:, 1:] = np.where(follow[:, 1:], self._next[toks[:, :-1]],
                               base[:, 1:])
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}


def pack_documents(docs, seq_len: int, pad_id: int = 0) -> np.ndarray:
    """Greedy packing of variable-length docs into fixed seq_len rows."""
    rows, cur = [], []
    for d in docs:
        d = list(d)
        while d:
            space = seq_len - len(cur)
            cur.extend(d[:space])
            d = d[space:]
            if len(cur) == seq_len:
                rows.append(cur)
                cur = []
    if cur:
        rows.append(cur + [pad_id] * (seq_len - len(cur)))
    return np.asarray(rows, dtype=np.int32)


def build_corpus(n_docs: int, retrieval_vocab: int = 2048,
                 doc_max: int = 64, gen_vocab: int = 32000,
                 embed_dim: int = 0, seed: int = 0):
    """Synthetic Zipf corpus for the RAG methods. Returns a
    ``core.methods.rag.Corpus``."""
    import jax.numpy as jnp
    from repro.core.methods.rag import Corpus

    rng = np.random.default_rng(seed)
    lens = rng.integers(doc_max // 4, doc_max, size=n_docs)
    ranks = np.arange(1, retrieval_vocab + 1, dtype=np.float64)
    p = ranks ** -1.1
    p /= p.sum()
    tf = np.zeros((n_docs, retrieval_vocab), np.int32)
    doc_tokens = np.zeros((n_docs, doc_max), np.int32)
    for i in range(n_docs):
        terms = rng.choice(retrieval_vocab, size=lens[i], p=p)
        np.add.at(tf[i], terms, 1)
        doc_tokens[i, : lens[i]] = terms % gen_vocab
    df = (tf > 0).sum(axis=0)
    idf = np.log((n_docs - df + 0.5) / (df + 0.5) + 1.0).astype(np.float32)
    emb = None
    if embed_dim:
        emb = rng.standard_normal((n_docs, embed_dim)).astype(np.float32)
        emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    return Corpus(
        tf=jnp.asarray(tf),
        doc_len=jnp.asarray(lens, jnp.float32),
        idf=jnp.asarray(idf),
        doc_tokens=jnp.asarray(doc_tokens),
        doc_embeds=None if emb is None else jnp.asarray(emb),
    )


def sample_queries(corpus, batch: int, n_terms: int, seed: int = 0):
    """Query term ids biased toward corpus terms (so BM25 has signal)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    vocab = corpus.tf.shape[1]
    docs = rng.integers(0, corpus.tf.shape[0], size=batch)
    out = np.zeros((batch, n_terms), np.int32)
    tf = np.asarray(corpus.tf)
    for i, d in enumerate(docs):
        terms = np.flatnonzero(tf[d])
        if len(terms) >= n_terms:
            out[i] = rng.choice(terms, size=n_terms, replace=False)
        else:
            out[i] = rng.integers(0, vocab, size=n_terms)
    return jnp.asarray(out)
