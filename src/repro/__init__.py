"""repro — TPU-native reproduction of "Understand and Accelerate Memory
Processing Pipeline for Large Language Model Inference" (He et al., 2026).

See DESIGN.md for the system inventory and hardware-adaptation notes.
"""

__version__ = "1.0.0"
