"""Stateless async router over a fleet of Engine replicas.

The paper's end-to-end claim (memory processing is 22%-97% of *serving*)
is a fleet-scale claim: N engines behind a router, mixed arrival traffic,
p50/p99 TTFT — not one engine stepped by a test harness. The router is
the request-level front of that fleet:

  * it owns ``EngineReplica`` workers, each an Engine pinned to a distinct
    device group (``hetero.policy.pick_devices_replicas``) so JAX's async
    dispatch overlaps their device work from one host thread;
  * it routes each :class:`Request` by ELIGIBILITY (a ``method_overrides
    ["method"]`` pin, retrieval opt-in), SESSION AFFINITY (every request
    of one session stays on one replica — KV/retrieval locality), then
    LEAST LOAD with a deterministic index tie-break;
  * it shares ONE ``RetrievalService`` corpus across all replicas (the
    service is capacity-padded and incremental-ingest, so a document
    ingested through any replica is visible to every replica's triggers);
  * the router itself holds no decode state — all serving state lives in
    the replicas' engines, the router only forwards and pumps.

``submit(Request) -> ResponseHandle`` and ``drain()`` mirror the
single-engine API, so the single-engine compatibility shim and the fleet
front are the same surface at different scales.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

from repro.serving.api import Request, ResponseHandle
from repro.serving.engine import ServeConfig
from repro.serving.events import StepEvents
from repro.serving.replica import EngineReplica


class Router:
    def __init__(self, replicas: Sequence[EngineReplica], *,
                 service=None):
        assert replicas, "a router needs at least one replica"
        self.replicas = list(replicas)
        self.service = service          # shared RetrievalService (or None)
        self._affinity: Dict = {}       # session -> replica index
        self._handles: Dict[int, ResponseHandle] = {}

    # ------------------------------------------------------------------
    # fleet construction
    # ------------------------------------------------------------------

    @classmethod
    def build(cls, cfg, params,
              sc: Union[ServeConfig, Sequence[ServeConfig]],
              n_replicas: Optional[int] = None, *,
              key=None, mem=None) -> "Router":
        """Build a fleet: one ServeConfig replicated ``n_replicas`` times,
        or a per-replica config list (heterogeneous methods). Device
        groups come from ``pick_devices_replicas``; every replica with a
        rag retrieval config is rewired onto ONE shared service."""
        from repro.hetero import policy as hpolicy

        if isinstance(sc, ServeConfig):
            assert n_replicas is not None and n_replicas >= 1
            cfgs = [sc] * n_replicas
        else:
            cfgs = list(sc)
            assert n_replicas is None or n_replicas == len(cfgs)
        groups = hpolicy.pick_devices_replicas(len(cfgs))
        service = cls._build_shared_service(cfgs, groups)
        replicas = []
        for i, rsc in enumerate(cfgs):
            if service is not None and rsc.retrieval is not None \
                    and getattr(rsc.retrieval, "kind", None) == "rag":
                rsc = dataclasses.replace(
                    rsc, retrieval=dataclasses.replace(
                        rsc.retrieval, service=service))
            replicas.append(EngineReplica(i, cfg, params, rsc, key=key,
                                          mem=mem, devices=groups[i]))
        return cls(replicas, service=service)

    @staticmethod
    def _build_shared_service(cfgs, groups):
        """One capacity-padded corpus service for the whole fleet, placed
        on the last device of the last group (an offload-side device on
        multi-device topologies; device 0 — transfer no-ops — otherwise)."""
        rcfgs = [c.retrieval for c in cfgs
                 if c.retrieval is not None
                 and getattr(c.retrieval, "kind", None) == "rag"]
        if not rcfgs:
            return None
        from repro.retrieval.service import RetrievalService
        r = rcfgs[0]
        if r.service is not None:       # caller already built one
            return r.service
        assert r.corpus is not None, "kind='rag' needs a corpus"
        return RetrievalService(r.corpus, k=r.k, device=groups[-1][-1],
                                capacity=r.capacity,
                                ingest_block=r.ingest_block)

    # ------------------------------------------------------------------
    # request-level API (mirrors Engine.submit/poll/drain)
    # ------------------------------------------------------------------

    def _route(self, req: Request) -> EngineReplica:
        if req.session is not None and req.session in self._affinity:
            return self.replicas[self._affinity[req.session]]
        cands = [r for r in self.replicas if r.can_serve(req)]
        if not cands:
            cands = self.replicas      # no eligible replica: best effort
        best = min(cands, key=lambda r: (r.load(), r.index))
        if req.session is not None:
            self._affinity[req.session] = best.index
        return best

    def submit(self, req: Request) -> ResponseHandle:
        """Route by affinity/eligibility/load and enqueue on the replica;
        the handle's ``replica`` field records the placement."""
        if req.rid in self._handles and not self._handles[req.rid].done:
            raise ValueError(f"request id {req.rid} already in flight")
        h = self._route(req).submit(req)
        self._handles[req.rid] = h
        return h

    def poll(self) -> StepEvents:
        """One fleet turn: pump every replica once (their device work
        overlaps under JAX async dispatch) and merge the events. The
        merged ``finished``/``fired`` slot ids are replica-local and kept
        only for counting; emissions carry globally-unique rids."""
        ev = StepEvents()
        for r in self.replicas:
            rev = r.poll()
            ev.emissions.extend(rev.emissions)
            ev.finished.extend(rev.finished)
            ev.fired.extend(rev.fired)
            ev.steps += rev.steps
        return ev

    def drain(self, max_steps: int = 100_000) -> Dict[int, ResponseHandle]:
        """Pump until every replica's queue and pool are empty (or stuck);
        returns all completed handles by rid."""
        steps = 0
        while steps < max_steps:
            busy = [r for r in self.replicas if r.busy()]
            if not busy:
                break
            alive = False
            for r in busy:
                rev = r.poll()
                steps += max(1, rev.steps)
                if r.made_progress(rev):
                    alive = True
                elif r.engine.queue and r.engine._inflight_h:
                    alive = True       # admission deferred; retry next turn
            if not alive:
                break                  # every busy replica is stuck
        return self.done()

    def done(self) -> Dict[int, ResponseHandle]:
        out: Dict[int, ResponseHandle] = {}
        for r in self.replicas:
            out.update(r.engine.done)
        return out

    def busy(self) -> bool:
        return any(r.busy() for r in self.replicas)

    def ingest(self, corpus) -> None:
        """Append documents to the fleet-shared corpus (visible to every
        replica's triggers from the next retrieval on)."""
        assert self.service is not None, "no shared retrieval service"
        self.service.ingest(corpus)

    # ------------------------------------------------------------------

    def report(self) -> Dict:
        done = self.done()
        ttfts = [h.ttft_s() for h in done.values()
                 if h.ttft_s() is not None]
        out = {
            "n_replicas": len(self.replicas),
            "requests_done": len(done),
            "sessions": len(self._affinity),
            "replicas": [r.report() for r in self.replicas],
        }
        if ttfts:
            out["ttft_s"] = {"mean": float(sum(ttfts) / len(ttfts)),
                             "max": float(max(ttfts))}
        if self.service is not None:
            out["shared_corpus"] = {"n_docs": int(self.service.n_docs),
                                    "capacity": int(self.service.capacity),
                                    "device": str(self.service.device)}
        return out
