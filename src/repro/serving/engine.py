"""Batched decode engine with the memory pipeline as a first-class feature.

* builds jitted prefill / decode steps (optionally on separate role meshes —
  the paper's prefill/decode disaggregation, Fig. 6b),
* wires the sparse-attention memory pipeline into decode via the placement
  policy: a traced lax.cond implements the paper's DYNAMIC FALLBACK — dense
  attention below ``min_context`` and above ``fallback_context``, the fused
  sparse pipeline in between (for pooled decode the cond is decided on the
  max length over live slots; masks inside the branch stay per-slot),
* continuous batching runs on a PAGED KV pool with PER-SLOT lengths: slots
  allocate/free fixed-size pages at admit/release (HBM scales with live
  tokens, not ``n_slots * max_len``), every slot decodes at its own RoPE
  position / cache offset / attention mask, admission prefill is batched
  over length buckets with a small set of pre-jitted shapes, and long
  prompts prefill in fixed-size chunks interleaved with decode steps,
* the legacy dense ``n_slots x max_len`` pool with the shared
  ``lengths.max()`` watermark is kept behind ``ServeConfig(paged=False)`` as
  the benchmark baseline (bench_batch_scaling old-vs-new comparison),
* ``ServeConfig(offload_cfg=OffloadConfig(...))`` routes the
  memory-processing stages through
  the heterogeneous offload executor (src/repro/hetero): lookahead
  selection on a second device, overlapped with decode, exchanging only
  page indices — the paper's §5 system emulated on JAX devices.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MemoryConfig
from repro.core import placement
from repro.core.methods import get_sparse_method
from repro.models import model as M
from repro.serving.api import Request, ResponseHandle
from repro.serving.events import StepEvents
from repro.serving.kv_cache import PagedKVPool, SlotManager

POOL_FAMILIES = ("dense", "moe", "audio", "vlm")


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@dataclasses.dataclass
class OffloadConfig:
    """Heterogeneous-offload topology as one nested config
    (``ServeConfig(offload_cfg=OffloadConfig(...))``).

    mode       "off" = inline sparse pipeline; "sync" = two-phase
               select->apply on the offload device but serialized;
               "overlap" = double-buffered lookahead selection overlapped
               with decode (the paper's heterogeneous execution).
    validate   replay each consumed selection and bit-check it.
    shards     >1 = one offload device per contiguous KV-sequence shard
               (hetero.sharded), index-only candidate merge.
    main_mesh  >1 = N-device main mesh running the apply phase
               sequence-parallel. Composes with ``shards``.
    """
    mode: str = "off"
    validate: bool = False
    shards: int = 1
    main_mesh: int = 1

    def __post_init__(self):
        if self.mode not in ("off", "sync", "overlap"):
            raise ValueError(
                f"offload mode must be 'off', 'sync' or 'overlap', "
                f"got {self.mode!r}")
        if self.shards < 1:
            raise ValueError(f"offload shards must be >= 1, "
                             f"got {self.shards}")
        if self.main_mesh < 1:
            raise ValueError(f"main_mesh must be >= 1, got {self.main_mesh}")
        if self.mode == "off" and (self.shards > 1 or self.main_mesh > 1):
            raise ValueError("shards/main_mesh need "
                             "OffloadConfig(mode='sync'|'overlap')")


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 4096
    n_slots: int = 8
    method: str = "none"       # none | dsa | seer | lserve
    tp: int = 16
    page: int = 16             # dsa micro-page size
    greedy: bool = True
    # --- paged continuous batching ---
    paged: bool = True         # False = legacy dense pool + shared watermark
    kv_page_size: int = 16     # physical KV page (pool granule)
    pool_pages: int = 0        # 0 = full backing; else arena size (oversubscribe)
    prefill_chunk: int = 128   # chunk span for chunked prefill
    chunk_threshold: int = 512 # prompts longer than this prefill in chunks
    view_buckets: bool = True  # size the decode view by max live length
                               # (pow2-bucketed) instead of max_len
    # --- heterogeneous offload (src/repro/hetero) ---
    # "off" = inline sparse pipeline; "sync" = two-phase select->apply on
    # the offload device but serialized (validation/benchmark baseline);
    # "overlap" = double-buffered lookahead selection overlapped with
    # decode (the paper's heterogeneous execution). Requires paged=True and
    # a sparse method (dsa | seer | lserve).
    offload: str = "off"
    offload_validate: bool = False  # replay each consumed selection + check
    # >1 shards the offload side over one device per KV-sequence shard
    # (hetero.sharded.ShardedHeteroExecutor): each shard keeps the page
    # summaries of its contiguous token window and ships only top-k
    # (vals, idx) candidates; the merged selection is bit-identical to
    # offload_shards=1 in both scheduling modes.
    offload_shards: int = 1
    # >1 builds an N-device MAIN mesh and runs the APPLY phase
    # sequence-parallel over it: the paged-pool view is sharded over the
    # sequence axis inside ``decode_step_paged_presel``'s page_attn seam
    # (distributed_paged_sparse_decode — both cond branches, sparse apply
    # AND dense fallback), and only (out, lse) pairs cross the mesh.
    # Composes with offload_shards=M: M selection shards + N apply shards
    # scale independently (paper Fig. 6a end to end). Requires a hetero
    # offload mode — the apply phase exists as a separate stage only under
    # the two-phase select->apply split.
    main_mesh: int = 1
    # --- retrieval subsystem (src/repro/retrieval) ---
    # A repro.retrieval.RetrievalConfig enables the document-memory service:
    # per-slot FLARE/DRAGIN triggers over the pooled decode logits, dynamic
    # RAG doc splices / MaC memory-bank embedding splices through the
    # chunked-extend path, inline or on the offload device (sync/overlap).
    # Composes with ``offload`` — retrieval slots share the pool with
    # sparse-attention slots. Requires paged=True.
    retrieval: Optional[object] = None
    # --- redesigned stepping/config surface -----------------------------
    # ``offload_cfg`` is the first-class surface for the offload topology;
    # the flat ``offload`` / ``offload_validate`` / ``offload_shards`` /
    # ``main_mesh`` fields above are kept as DEPRECATED aliases that now
    # emit a ``DeprecationWarning`` when set explicitly. Flat non-default
    # values win (pre-existing call sites behave unchanged); otherwise the
    # nested config populates the flat fields. The two surfaces stay in
    # sync through ``dataclasses.replace`` on either (a coherent
    # flat == nested replace does not warn).
    offload_cfg: Optional[OffloadConfig] = None
    # decode steps fused into one on-device lax.scan per host dispatch
    # (serving/fused.py): K>1 trades per-token host round-trips for one
    # dispatch per window, with masked early exit back to the host when a
    # slot finishes or a retrieval trigger fires. 1 = stepped host loop.
    fused_steps: int = 1

    _FLAT_OFFLOAD_DEFAULT = ("off", False, 1, 1)

    def __post_init__(self):
        flat = (self.offload, self.offload_validate, self.offload_shards,
                self.main_mesh)
        if self.offload_cfg is not None and flat == self._FLAT_OFFLOAD_DEFAULT:
            oc = self.offload_cfg
            self.offload = oc.mode
            self.offload_validate = oc.validate
            self.offload_shards = oc.shards
            self.main_mesh = oc.main_mesh
        else:
            nested = None if self.offload_cfg is None else (
                self.offload_cfg.mode, self.offload_cfg.validate,
                self.offload_cfg.shards, self.offload_cfg.main_mesh)
            if flat != self._FLAT_OFFLOAD_DEFAULT and nested != flat:
                # an explicitly-set flat kwarg (not the mirror of a
                # coherent nested config carried through replace())
                warnings.warn(
                    "flat ServeConfig offload kwargs (offload=, "
                    "offload_validate=, offload_shards=, main_mesh=) are "
                    "deprecated; use ServeConfig(offload_cfg="
                    "OffloadConfig(mode=..., validate=..., shards=..., "
                    "main_mesh=...))", DeprecationWarning, stacklevel=3)
            # (re)derive the nested view — also validates the flat fields
            self.offload_cfg = OffloadConfig(
                mode=self.offload, validate=self.offload_validate,
                shards=self.offload_shards, main_mesh=self.main_mesh)
        if self.fused_steps < 1:
            raise ValueError(
                f"fused_steps must be >= 1, got {self.fused_steps}")
        if self.fused_steps > 1 and not self.paged:
            raise ValueError("fused_steps > 1 fuses the PAGED decode loop "
                             "(ServeConfig(paged=True))")


class Engine:
    def __init__(self, cfg: ArchConfig, params, sc: ServeConfig,
                 key=None, mem: Optional[MemoryConfig] = None,
                 devices=None):
        self.cfg = cfg
        # ``devices``: pin this engine to a device GROUP (a fleet replica's
        # slice of the machine, hetero.policy.pick_devices_replicas).
        # Committing the params to the group's first device pins every jit
        # dispatch there; the remaining devices serve the offload/retrieval
        # side. None = the process-default device (single-engine behavior,
        # unchanged).
        self.devices = tuple(devices) if devices else None
        if self.devices is not None:
            params = jax.device_put(params, self.devices[0])
        self.params = params
        self.mem = mem or cfg.memory.replace(method=sc.method)
        # the paged pipeline needs the cache length page-aligned; the paged
        # pool additionally needs it kv-page aligned
        gran = max(sc.page, self.mem.block_size,
                   self.mem.block_size * self.mem.pages_per_physical
                   if sc.method == "lserve" else 1)
        if sc.method == "none":
            gran = 1
        gran = math.lcm(gran, sc.kv_page_size if sc.paged else 1)
        # sharded offload: every shard window must cover a whole number of
        # selection pages AND kv pages, so align max_len to gran * shards
        gran *= max(sc.offload_shards, 1)
        # main-mesh apply: pow2-bucketed decode views are multiples of the
        # granule, so folding the mesh size in keeps every bucket length
        # divisible by n_shards * page_size — the sequence-parallel apply's
        # shard-granularity contract (distributed_paged_sparse_decode
        # asserts it; an unaligned bucket used to trip it)
        gran *= max(sc.main_mesh, 1)
        if sc.max_len % gran:
            sc = dataclasses.replace(
                sc, max_len=((sc.max_len + gran - 1) // gran) * gran)
        self.sc = sc
        self._gran = gran
        self.sparse_params = None
        sparse_fn = None
        if sc.method != "none" and cfg.family != "ssm":
            init_fn, mk = get_sparse_method(sc.method)
            self.sparse_params = init_fn(
                key if key is not None else jax.random.PRNGKey(0),
                cfg, self.mem, stacked=cfg.family != "hybrid")
            kw = {"page": sc.page} if sc.method == "dsa" else {}
            raw = mk(cfg, self.mem, tp=sc.tp, **kw)
            mem = self.mem

            def fallback_fn(q, kc, vc, length, sp, k_new=None):
                """Paper's dynamic fallback as a traced cond.

                ``length`` is a scalar (per-request decode) or a per-slot
                vector (pooled decode); the cond predicate is batch-level
                (max over slots — a jitted cond cannot branch per row), the
                branch itself masks per slot.
                """
                from repro.models import attention as A

                def dense(_):
                    return A.attention_decode(q, kc, vc, length, cfg, tp=sc.tp)

                def sparse(_):
                    return raw(q, kc, vc, length, sp, k_new=k_new)

                use_sparse = placement.traced_use_sparse(length, mem)
                return jax.lax.cond(use_sparse, sparse, dense, None)

            sparse_fn = fallback_fn
        self._sparse_fn = sparse_fn

        # --- main mesh (sequence-parallel apply) ---------------------------
        self.main_mesh = None
        self._mesh_sharding = None       # replicated NamedSharding on it
        exec_devs = None                 # executor placement override
        if sc.main_mesh > 1:
            assert self.devices is None, \
                "Engine(devices=...) pins a replica's device group; it " \
                "does not compose with main_mesh — the mesh picks its own " \
                "devices (hetero.policy.pick_devices_mesh)"
            assert sc.paged, "main_mesh shards the paged apply"
            assert sc.offload in ("sync", "overlap"), \
                "main_mesh needs ServeConfig(offload='sync'|'overlap') — " \
                "the sequence-parallel apply runs the two-phase presel step"
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.hetero import policy as hpolicy
            from repro.launch.mesh import mesh_from_devices
            mains, offs = hpolicy.pick_devices_mesh(
                sc.main_mesh, max(sc.offload_shards, 1))
            self.main_mesh = mesh_from_devices(mains, ("seq",))
            self._mesh_sharding = NamedSharding(self.main_mesh,
                                                PartitionSpec())
            exec_devs = (mains[0],
                         offs if sc.offload_shards > 1 else offs[0])
        elif self.devices is not None:
            # replica group: main device first, offload side round-robin
            # over the rest (over the whole group when it has one device —
            # transfers degenerate to no-ops, as in pick_devices)
            off_pool = self.devices[1:] or self.devices
            if sc.offload_shards > 1:
                exec_devs = (self.devices[0],
                             tuple(off_pool[i % len(off_pool)]
                                   for i in range(sc.offload_shards)))
            else:
                exec_devs = (self.devices[0], off_pool[0])

        self.hetero = None
        if sc.offload != "off":
            assert sc.offload in ("sync", "overlap"), sc.offload
            assert sc.paged, "hetero offload runs over the paged pool"
            assert sc.method in ("dsa", "seer", "lserve"), \
                "hetero offload needs a sparse memory-processing method"
            assert cfg.family in POOL_FAMILIES
            if sc.offload_shards > 1:
                from repro.hetero import ShardedHeteroExecutor
                self.hetero = ShardedHeteroExecutor(
                    cfg, self.mem, self.sc, self.sparse_params,
                    mode=sc.offload, validate=sc.offload_validate,
                    n_shards=sc.offload_shards, devices=exec_devs,
                    main_mesh=self.main_mesh)
            else:
                from repro.hetero import HeteroExecutor
                self.hetero = HeteroExecutor(
                    cfg, self.mem, self.sc, self.sparse_params,
                    mode=sc.offload, validate=sc.offload_validate,
                    devices=exec_devs, main_mesh=self.main_mesh)
        else:
            assert sc.offload_shards <= 1, \
                "offload_shards needs ServeConfig(offload='sync'|'overlap')"

        self.retrieval = None
        if sc.retrieval is not None:
            assert sc.paged, "the retrieval subsystem serves the paged pool"
            assert cfg.family in POOL_FAMILIES
            from repro.retrieval import RetrievalExecutor
            rdevs = self.hetero.devices if self.hetero else None
            if rdevs is None and exec_devs is not None:
                rdevs = (exec_devs[0],
                         exec_devs[1][0] if isinstance(exec_devs[1], tuple)
                         else exec_devs[1])
            self.retrieval = RetrievalExecutor(
                cfg, self.sc, sc.retrieval, self.params, key=key,
                devices=rdevs)

        self._prefill = jax.jit(
            lambda p, toks: M.prefill(p, cfg, toks, max_len=sc.max_len,
                                      tp=sc.tp),
        )
        self._decode = jax.jit(
            lambda p, tok, caches, sp: M.decode_step(
                p, cfg, tok, caches, tp=sc.tp,
                sparse_fn=self._sparse_fn,
                sparse_params=sp),
        )
        # pooled-path jits (built lazily; bucket/chunk shapes cached by key).
        # k_pages/v_pages are DONATED: the engine replaces its references
        # with the outputs right after each call, so XLA may update the pool
        # in place instead of copying the whole arena every token (on CPU
        # donation is a no-op warning; on TPU it is the difference between
        # O(touched pages) and O(pool) per-step HBM traffic).
        self._decode_paged = jax.jit(
            lambda p, tok, kp, vp, table, lengths, live, sp:
            M.decode_step_paged(
                p, cfg, tok,
                {"k_pages": kp, "v_pages": vp, "page_table": table,
                 "lengths": lengths},
                live, tp=sc.tp,
                sparse_fn=self._sparse_fn, sparse_params=sp),
            donate_argnums=(2, 3))
        self._bucket_fns: Dict[Tuple[int, int], callable] = {}
        self._extend_fns: Dict[Tuple[int, bool], callable] = {}
        self._splice_fns: Dict[Tuple[int, int], callable] = {}
        self._fused_fns: Dict[Tuple, callable] = {}   # inline fused loops
        self._table_view_cache = None  # (npv, table_version) -> sliced view

        self.slots = SlotManager(sc.n_slots, sc.max_len)
        self.pool: Optional[PagedKVPool] = None
        self.caches = None            # legacy dense pool
        # chunked-prefill state: slot -> [request_id, prompt np, next_pos]
        self._chunks: Dict[int, list] = {}
        # host_steps counts step_pool dispatch boundaries, decode_steps the
        # device steps behind them — their ratio is the host-dispatch
        # amortization a fused window buys (bench_fused_decode)
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0,
                      "host_steps": 0, "decode_steps": 0}

        # --- request-level admission state (api.Request is the ONE way
        # into the pool; the compatibility Scheduler and the fleet router
        # both go through submit/poll) ---------------------------------
        self.prefill_token_budget = 2048   # per-poll admission budget
        self.queue: collections.deque = collections.deque()
        self._handles: Dict[int, ResponseHandle] = {}
        self._inflight_h: Dict[int, ResponseHandle] = {}
        self.done: Dict[int, ResponseHandle] = {}
        self._auto_rid = 0                 # generate() uses negative rids
        self._polled_prefill = False

    # ------------------------------------------------------------------
    # request-level serving API (submit / poll / drain)
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> ResponseHandle:
        """Enqueue one :class:`Request`. Admission happens inside ``poll``
        (FCFS under the prefill token budget, chunked for long prompts);
        the returned handle carries the live token stream and timing."""
        if not isinstance(req, Request):
            raise TypeError(
                f"submit() takes a serving.Request, got {type(req)!r}")
        if req.rid in self._handles and not self._handles[req.rid].done:
            raise ValueError(f"request id {req.rid} already in flight")
        h = ResponseHandle(req)
        self._handles[req.rid] = h
        self.queue.append(req)
        return h

    def queue_depth(self) -> int:
        return len(self.queue)

    def busy(self) -> bool:
        return bool(self.queue or self._inflight_h)

    def _next_rid(self) -> int:
        """Fresh internal rid (negative: never collides with caller ids)."""
        self._auto_rid -= 1
        return self._auto_rid

    def _mark_admitted(self, req: Request) -> None:
        h = self._handles[req.rid]
        h.admitted = time.perf_counter()
        self._inflight_h[req.rid] = h

    def _admit_from_queue(self) -> None:
        """FCFS batch admission within the per-poll prefill token budget:
        queued short prompts admit TOGETHER (one bucketed prefill per
        distinct bucket length), long prompts switch to chunked mode
        (pages reserved now, the prompt streams in ``prefill_chunk`` spans
        interleaved with decode), rejections re-queue at the FRONT."""
        if not self.queue:
            return
        budget = self.prefill_token_budget
        batch: List[Request] = []
        while self.queue and budget > 0:
            req = self.queue[0]
            plen = len(req)
            chunked = self.sc.paged and bool(
                req.override("chunked", plen > self.sc.chunk_threshold))
            if chunked:
                if not self._admit_chunked(req.rid, req.tokens, req.max_new,
                                           retrieval=req.retrieval):
                    break
                self.queue.popleft()
                self._mark_admitted(req)
                continue
            if batch and plen > budget:
                break                      # defer the rest to the next poll
            batch.append(req)
            self.queue.popleft()
            budget -= plen
        if not batch:
            return
        oks = self._admit_many(
            [(r.rid, r.tokens, r.max_new) for r in batch],
            retrieval=[r.retrieval for r in batch])
        # re-queue rejections at the FRONT, preserving FCFS order
        for r, ok in zip(reversed(batch), reversed(oks)):
            if ok:
                self._mark_admitted(r)
            else:
                self.queue.appendleft(r)

    def _dispatch(self, ev: StepEvents) -> None:
        """Route emissions into their ResponseHandles; finish handles that
        reached ``max_new`` and stamp the timing marks."""
        now = time.perf_counter()
        for rid, _slot, tok in ev.emissions:
            h = self._inflight_h.get(rid)
            if h is None:
                continue
            if h.first_token_t is None:
                h.first_token_t = now
            h.tokens.append(int(tok))
            if len(h.tokens) >= h.request.max_new:
                h.finished = now
                self.done[rid] = h
                del self._inflight_h[rid]

    def poll(self) -> StepEvents:
        """One serving turn: admit from the queue (budgeted), advance any
        chunked prefill, run one pooled-decode dispatch, and route the
        emissions into their handles. The fleet router and ``drain`` both
        pump this; it is safe to call on an idle engine."""
        self._ensure_pool()
        self._admit_from_queue()
        self._polled_prefill = bool(self.has_prefill_work()
                                    and self.prefill_step())
        ev = self.step_pool()
        self._dispatch(ev)
        return ev

    def drain(self, max_steps: int = 10_000) -> Dict[int, ResponseHandle]:
        """Pump ``poll`` until queue and pool are empty (or the head
        request can never admit); returns completed handles by rid."""
        steps = 0
        while (self.queue or self._inflight_h) and steps < max_steps:
            ev = self.poll()
            # a fused window consumes several device steps in one
            # dispatch; idle dispatches still count as one turn
            steps += max(1, ev.steps)
            if not ev and not self._polled_prefill:
                if self.has_retrieval_work() or self.has_prefill_work():
                    continue   # retrieval in flight, or a splice chunk
                               # was queued DURING this step's decode
                if not self.queue:
                    break
                if not self._inflight_h:
                    break      # head request can never admit: stuck
        return dict(self.done)

    def throughput_tokens_per_s(self) -> float:
        if not self.done:
            return 0.0
        toks = sum(len(h.tokens) for h in self.done.values())
        t0 = min(h.submitted for h in self.done.values())
        t1 = max(h.finished for h in self.done.values())
        return toks / max(t1 - t0, 1e-9)

    # ------------------------------------------------------------------
    # simple batched API
    # ------------------------------------------------------------------

    def generate(self, prompts: jnp.ndarray, max_new: int) -> np.ndarray:
        """prompts [B, S] -> generated [B, max_new] (greedy).

        Thin wrapper over ``submit``+``drain``: each row becomes a
        :class:`Request` through the one admission path and the pooled
        continuous-batching loop serves them — the per-row streams are
        bit-identical to the legacy per-batch dense-cache loop (the
        pooled-vs-dense equality the paged tests pin). Engines the pool
        cannot serve (ssm caches, ``paged=False``, prompts that don't
        fit, a pool already mid-flight) fall back to that loop unchanged.
        """
        prompts_np = np.asarray(prompts)
        B, S = prompts_np.shape
        poolable = (self.sc.paged and self.cfg.family in POOL_FAMILIES
                    and S + max_new <= self.sc.max_len
                    and not self.busy()
                    and not self.slots.live_mask().any())
        if not poolable:
            return self._generate_batched(prompts, max_new)
        handles = [self.submit(Request(self._next_rid(), row, max_new,
                                       retrieval=False))
                   for row in prompts_np]
        self.drain()
        for h in handles:        # generate() is a query, not a resident
            self.done.pop(h.rid, None)       # request: leave no residue
            self._handles.pop(h.rid, None)
        assert all(h.done for h in handles), \
            [h.rid for h in handles if not h.done]
        return np.stack([np.asarray(h.tokens, np.int32) for h in handles])

    def _generate_batched(self, prompts: jnp.ndarray,
                          max_new: int) -> np.ndarray:
        """Legacy batched dense-cache loop (the pre-pool oracle)."""
        t0 = time.perf_counter()
        logits, caches = jax.block_until_ready(
            self._prefill(self.params, prompts))
        self.stats["prefill_s"] += time.perf_counter() - t0
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = []
        t0 = time.perf_counter()
        for _ in range(max_new):
            out.append(tok)
            logits, caches = self._decode(self.params, tok, caches,
                                          self.sparse_params)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["tokens"] += int(prompts.shape[0]) * max_new
        return np.stack([np.asarray(t) for t in out], axis=1)

    # ------------------------------------------------------------------
    # continuous batching (dense-cache families)
    # ------------------------------------------------------------------

    def _ensure_pool(self):
        if self.sc.paged:
            if self.pool is None:
                assert self.cfg.family in POOL_FAMILIES, \
                    "continuous batching requires dense KV caches"
                self.pool = PagedKVPool(
                    self.cfg, self.sc.n_slots, self.sc.max_len,
                    page_size=self.sc.kv_page_size,
                    total_pages=self.sc.pool_pages, tp=self.sc.tp)
                if self._mesh_sharding is not None:
                    # commit the pool buffers REPLICATED over the main mesh
                    # from the start: every jit touching them (apply with
                    # the shard_map seam, prefill splice, chunked extend)
                    # then compiles for the mesh, and buffer donation stays
                    # honorable (replicated in == replicated out)
                    for k in ("k_pages", "v_pages"):
                        self.pool.device[k] = jax.device_put(
                            self.pool.device[k], self._mesh_sharding)
                self._pending = np.zeros((self.sc.n_slots,), np.int32)
        elif self.caches is None:
            assert self.cfg.family in POOL_FAMILIES, \
                "continuous batching requires dense KV caches"
            self.caches = M.make_cache(self.cfg, self.sc.n_slots,
                                       self.sc.max_len, tp=self.sc.tp)
            self._pending = np.zeros((self.sc.n_slots,), np.int32)

    # -- admission (batched, length-bucketed prefill) -------------------

    def _bucket_len(self, prompt_len: int) -> int:
        ps = self.sc.kv_page_size
        b = _next_pow2(max(prompt_len, ps))
        b = ((b + ps - 1) // ps) * ps
        return min(b, self.sc.max_len)

    def _get_bucket_fn(self, B: int, Sb: int):
        key = (B, Sb)
        if key not in self._bucket_fns:
            cfg, sc = self.cfg, self.sc
            cq = self.hetero is not None
            self._bucket_fns[key] = jax.jit(
                lambda p, toks, lens: M.prefill_bucketed(p, cfg, toks, lens,
                                                         tp=sc.tp,
                                                         collect_q=cq))
        return self._bucket_fns[key]

    def _get_splice_fn(self, B: int, n_pages: int):
        key = (B, n_pages)
        if key not in self._splice_fns:
            ps = self.sc.kv_page_size

            def splice(kp, vp, k, v, dest):
                # k/v [L, B, Sb, KV, hd] -> pages [L, B*n_pages, ps, KV, hd]
                Lc, Bc = k.shape[0], k.shape[1]
                kpg = k.reshape(Lc, Bc * n_pages, ps, *k.shape[3:])
                vpg = v.reshape(Lc, Bc * n_pages, ps, *v.shape[3:])
                flat = dest.reshape(-1)
                return kp.at[:, flat].set(kpg), vp.at[:, flat].set(vpg)

            self._splice_fns[key] = jax.jit(splice, donate_argnums=(0, 1))
        return self._splice_fns[key]

    def _admit_many(self, requests: List[Tuple[int, np.ndarray, int]],
                    retrieval: Optional[List] = None) -> List[bool]:
        """Admit a batch of (request_id, prompt, max_new): one bucketed
        prefill per distinct bucket length instead of one per request.
        ``retrieval[i]`` opts request i in/out of the retrieval service
        (None = service default: on when configured). Internal — callers
        admit through ``submit``."""
        self._ensure_pool()
        if not self.sc.paged:
            return [self._admit_one(rid, p, mn) for rid, p, mn in requests]
        admitted: Dict[int, List] = {}   # bucket_len -> [(slot, prompt)]
        ok: List[bool] = []
        for i, (rid, prompt, max_new) in enumerate(requests):
            prompt = np.asarray(prompt)
            total = len(prompt) + max_new
            if total > self.sc.max_len or not self.pool.can_alloc(total):
                ok.append(False)
                break                    # FCFS: don't let later requests
            slot = self.slots.admit(rid, len(prompt), max_new)
            if slot is None:             # jump a rejected head (starvation)
                ok.append(False)
                break
            assert self.pool.alloc(slot, total)
            admitted.setdefault(self._bucket_len(len(prompt)), []).append(
                (slot, prompt))
            ok.append(True)
            if self.retrieval is not None:
                self.retrieval.on_admit(
                    slot, prompt,
                    retrieval[i] if retrieval is not None else None)
        ok.extend([False] * (len(requests) - len(ok)))
        t0 = time.perf_counter()
        for Sb, group in admitted.items():
            self._prefill_bucket(Sb, group)
        self.stats["prefill_s"] += time.perf_counter() - t0
        return ok

    def _prefill_bucket(self, Sb: int, group: List[Tuple[int, np.ndarray]]):
        """One jitted prefill over a length bucket + one page splice."""
        ps = self.sc.kv_page_size
        B = len(group)
        toks = np.zeros((B, Sb), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, (_, prompt) in enumerate(group):
            toks[i, : len(prompt)] = prompt
            lens[i] = len(prompt)
        out = self._get_bucket_fn(B, Sb)(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        if self.hetero is not None:
            logits, k, v, q_last = out
            self.hetero.on_admit([slot for slot, _ in group], k, lens,
                                 q_last)
        else:
            logits, k, v = out
        n_pages = Sb // ps
        dest = np.zeros((B, n_pages), np.int32)
        for i, (slot, _) in enumerate(group):
            dest[i] = self.pool.table[slot, :n_pages]
        kp, vp = self._get_splice_fn(B, n_pages)(
            self.pool.device["k_pages"], self.pool.device["v_pages"],
            k, v, jnp.asarray(dest))
        self.pool.device["k_pages"], self.pool.device["v_pages"] = kp, vp
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for i, (slot, _) in enumerate(group):
            self._pending[slot] = nxt[i]

    def _admit_one(self, request_id: int, prompt: np.ndarray, max_new: int,
                   retrieval: Optional[bool] = None) -> bool:
        """Prefill one request into a free slot (insertion into the pool)."""
        if self.sc.paged:
            return self._admit_many([(request_id, np.asarray(prompt),
                                      max_new)], retrieval=[retrieval])[0]
        assert self.cfg.family in POOL_FAMILIES, \
            "continuous batching requires dense KV caches"
        self._ensure_pool()
        slot = self.slots.admit(request_id, len(prompt), max_new)
        if slot is None:
            return False
        logits, c1 = self._prefill(self.params, jnp.asarray(prompt)[None])
        S = len(prompt)
        # splice the single-sequence cache into the pool at `slot`
        self.caches["k"] = jax.lax.dynamic_update_slice(
            self.caches["k"], c1["k"], (0, slot, 0, 0, 0))
        self.caches["v"] = jax.lax.dynamic_update_slice(
            self.caches["v"], c1["v"], (0, slot, 0, 0, 0))
        self._pending[slot] = int(jnp.argmax(logits[0]))
        return True

    # -- chunked prefill (long prompts, interleaved with decode) --------

    def _admit_chunked(self, request_id: int, prompt: np.ndarray,
                       max_new: int,
                       retrieval: Optional[bool] = None) -> bool:
        """Allocate slot + pages now; the prompt itself is prefilled in
        ``prefill_chunk``-sized spans by ``prefill_step`` so long prompts
        don't stall the decode pool."""
        assert self.sc.paged, "chunked prefill needs the paged pool"
        self._ensure_pool()
        prompt = np.asarray(prompt)
        total = len(prompt) + max_new
        if total > self.sc.max_len or not self.pool.can_alloc(total):
            return False
        slot = self.slots.admit(request_id, len(prompt), max_new)
        if slot is None:
            return False
        assert self.pool.alloc(slot, total)
        self.slots.slots[slot].length = 0      # grows as chunks land
        self._chunks[slot] = [request_id, prompt, 0, False]
        if self.hetero is not None:
            self.hetero.on_admit_slot(slot)
        if self.retrieval is not None:
            self.retrieval.on_admit(slot, prompt, retrieval)
        return True

    def has_prefill_work(self) -> bool:
        return bool(self._chunks)

    def _get_extend_fn(self, C: int, embeds: bool = False):
        key = (C, embeds)
        if key not in self._extend_fns:
            cfg, sc = self.cfg, self.sc
            ckq = self.hetero is not None
            if embeds:
                fn = lambda p, toks, kp, vp, table, lengths, nv, xe, er: \
                    M.extend_paged(
                        p, cfg, toks,
                        {"k_pages": kp, "v_pages": vp, "page_table": table,
                         "lengths": lengths},
                        nv, tp=sc.tp, collect_kq=ckq, x_embeds=xe,
                        emb_rows=er)
            else:
                fn = lambda p, toks, kp, vp, table, lengths, nv: \
                    M.extend_paged(
                        p, cfg, toks,
                        {"k_pages": kp, "v_pages": vp, "page_table": table,
                         "lengths": lengths},
                        nv, tp=sc.tp, collect_kq=ckq)
            self._extend_fns[key] = jax.jit(fn, donate_argnums=(2, 3))
        return self._extend_fns[key]

    def prefill_step(self) -> bool:
        """Advance every mid-prefill slot by one chunk — admission prompts
        and retrieval splices alike (retrieved documents / MaC embeddings
        ride the same chunked-extend machinery under the same budget).
        Returns True if any chunk work was done."""
        if not self._chunks:
            return False
        self._ensure_pool()
        C = self.sc.prefill_chunk
        n = self.sc.n_slots
        toks = np.zeros((n, C), np.int32)
        n_valid = np.zeros((n,), np.int32)
        emb_rows = np.zeros((n,), bool)
        x_embeds = None
        for slot, (rid, payload, pos, is_emb) in self._chunks.items():
            take = min(C, len(payload) - pos)
            if is_emb:
                if x_embeds is None:
                    x_embeds = np.zeros((n, C, self.cfg.d_model), np.float32)
                x_embeds[slot, :take] = payload[pos: pos + take]
                emb_rows[slot] = True
            else:
                toks[slot, :take] = payload[pos: pos + take]
            n_valid[slot] = take
        lengths = np.asarray([s.length for s in self.slots.slots], np.int32)
        lengths = np.where(n_valid > 0, lengths, 0)
        t0 = time.perf_counter()
        table = self._table_view(lengths, extra=C)
        args = (self.params, jnp.asarray(toks), self.pool.device["k_pages"],
                self.pool.device["v_pages"], table, jnp.asarray(lengths),
                jnp.asarray(n_valid))
        if x_embeds is not None:
            out = self._get_extend_fn(C, embeds=True)(
                *args, jnp.asarray(x_embeds), jnp.asarray(emb_rows))
        else:
            out = self._get_extend_fn(C)(*args)
        logits, pool = out[0], out[1]
        self.pool.device["k_pages"] = pool["k_pages"]
        self.pool.device["v_pages"] = pool["v_pages"]
        self.stats["prefill_s"] += time.perf_counter() - t0
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        finished: List[int] = []     # slots whose payload (admission
        for slot in list(self._chunks):  # prompt or splice) completed

            rid, payload, pos, is_emb = self._chunks[slot]
            take = int(n_valid[slot])
            self.slots.slots[slot].length += take
            if pos + take >= len(payload):
                self._pending[slot] = nxt[slot]
                del self._chunks[slot]
                finished.append(slot)
            else:
                self._chunks[slot][2] = pos + take
        if self.hetero is not None:
            # per-slot lookahead invalidation: only the finishing slots'
            # selection rows go dirty — a retrieval splice landing in one
            # slot no longer discards every other slot's valid lookahead
            k_span, q_last = out[2], out[3]
            self.hetero.on_extend(k_span, q_last, lengths, n_valid, finished)
        return True

    # -- pooled decode --------------------------------------------------

    def _view_len(self, needed: int) -> int:
        """Logical length of the gathered decode view: enough pages for the
        longest live slot, bucketed (pow2 multiples of the alignment granule)
        so the jit cache stays small. This is what kills the watermark tax —
        a pool whose longest live sequence is 300 tokens attends over a
        512-token view, not ``max_len``."""
        if not self.sc.view_buckets:
            return self.sc.max_len
        g = self._gran
        units = _next_pow2(max(1, -(-needed // g)))
        return min(g * units, self.sc.max_len)

    def _table_view(self, lengths: np.ndarray, extra: int = 1) -> jnp.ndarray:
        """Page table restricted to the bucketed view length.

        The slice is cached on (view pages, pool.table_version): steady-state
        decode re-slices (and re-uploads) nothing — the cache invalidates
        only when the bucket changes or a host-side table edit (admission,
        release, splice) bumps the pool's version counter."""
        needed = int(lengths.max()) + extra if lengths.size else 1
        vl = self._view_len(needed)
        npv = vl // self.sc.kv_page_size
        key = (npv, self.pool.table_version)
        if self._table_view_cache is None or self._table_view_cache[0] != key:
            self._table_view_cache = (
                key, self.pool.device["page_table"][:, :npv])
        return self._table_view_cache[1]

    def _decode_live(self) -> np.ndarray:
        """Slots that decode this step: live, not mid-prefill, and not
        paused awaiting an overlapped retrieval result."""
        live = self.slots.live_mask()
        for slot in self._chunks:
            live[slot] = False
        if self.retrieval is not None:
            live &= ~self.retrieval.waiting_mask()
        return live

    def _fused_window(self) -> int:
        """Width of the next fused decode window. 1 = stepped host loop.
        Fused windows only open when the host has nothing to interleave:
        no chunked prefill pending and the retrieval subsystem quiescent
        (in-flight queries and waiting slots need per-step host turns)."""
        K = self.sc.fused_steps
        if K <= 1 or not self.sc.paged or self._chunks:
            return 1
        if self.retrieval is not None and self.retrieval.busy():
            return 1
        return K

    def step_pool(self) -> StepEvents:
        """One host dispatch of the decode loop; returns a ``StepEvents``
        (iterating it yields the (request_id, slot, token) emissions the
        old list API returned). Stepped path: one decode step for every
        live slot. Fused path (``fused_steps`` K > 1): up to K steps run
        on device in one ``lax.scan`` and the host replays the emitted
        event log. Paged path: per-slot lengths (each slot attends,
        writes, and rotates at its own position); legacy path: shared
        ``lengths.max()`` watermark."""
        self._ensure_pool()
        if not self.sc.paged:
            return self._step_pool_dense()
        live = self._decode_live()
        if not live.any():
            if self.retrieval is not None:
                self._retrieval_idle()
            return StepEvents()
        K = self._fused_window()
        if K > 1:
            return self._step_pool_fused(live, K)
        lengths = np.where(live, self.slots.lengths(), 0).astype(np.int32)
        t0 = time.perf_counter()
        table = self._table_view(lengths)
        tok = jnp.asarray(self._pending)
        if self.hetero is not None:
            logits, pool = self.hetero.decode(
                self.params, tok, self.pool.device, table, lengths, live)
        else:
            logits, pool = self._decode_paged(
                self.params, tok, self.pool.device["k_pages"],
                self.pool.device["v_pages"], table, jnp.asarray(lengths),
                jnp.asarray(live), self.sparse_params)
        self.pool.device["k_pages"] = pool["k_pages"]
        self.pool.device["v_pages"] = pool["v_pages"]
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["host_steps"] += 1
        self.stats["decode_steps"] += 1
        ev = StepEvents(steps=1)
        for i in np.flatnonzero(live):
            rid = self.slots.slots[i].request_id
            ev.emissions.append((rid, int(i), int(self._pending[i])))
            if self.retrieval is not None:
                self.retrieval.note_token(int(i), int(self._pending[i]))
            self._pending[i] = nxt[i]
        self.stats["tokens"] += len(ev.emissions)
        self.slots.step(live)
        for i in np.flatnonzero(live):
            if self.slots.slots[i].done:
                ev.finished.append(int(i))
                self.pool.release(int(i))
                if self.retrieval is not None:
                    self.retrieval.on_release(int(i))
        if self.retrieval is not None:
            ev.fired.extend(self._retrieval_step(logits, live, lengths))
        return ev

    # -- fused multi-step decode (serving/fused.py) ---------------------

    def _fused_fn_inline(self, n_pages_view: int, K: int, trigger):
        key = (n_pages_view, K, trigger)
        if key not in self._fused_fns:
            from repro.serving.fused import make_fused_paged
            fn = make_fused_paged(self.cfg, self.mem, self.sc, K=K,
                                  trigger=trigger,
                                  sparse_fn=self._sparse_fn)
            self._fused_fns[key] = jax.jit(fn, donate_argnums=(3, 4))
        return self._fused_fns[key]

    def _decode_fused_inline(self, table, lengths, live, K, gen, maxnew,
                             armed, arm_after, trigger):
        fn = self._fused_fn_inline(int(table.shape[1]), K, trigger)
        outs = fn(self.params, self.sparse_params,
                  jnp.asarray(self._pending),
                  self.pool.device["k_pages"], self.pool.device["v_pages"],
                  table, jnp.asarray(lengths), jnp.asarray(live),
                  jnp.asarray(gen), jnp.asarray(maxnew),
                  jnp.asarray(armed), jnp.asarray(arm_after))
        nsteps = int(jax.block_until_ready(outs["nsteps"]))
        return {"k_pages": outs["k_pages"], "v_pages": outs["v_pages"],
                "pending": outs["pending"], "nsteps": nsteps,
                "emits": np.asarray(outs["emits"]),
                "fired": np.asarray(outs["fired"])}

    def _step_pool_fused(self, live: np.ndarray, K: int) -> StepEvents:
        """Run up to K decode steps in one jitted scan, then replay the
        emitted per-step event log through the exact bookkeeping the
        stepped path runs — token-for-token identical emissions, finish
        order, retrieval launches, and pool accounting. The scan stops
        early (masked no-ops, ``nsteps`` reports the real count) when any
        slot finishes or fires a trigger, handing control back to the host
        for admission/splice servicing at the same step boundary the
        stepped loop would have."""
        sl = self.slots.slots
        lengths = np.where(live, self.slots.lengths(), 0).astype(np.int32)
        gen = np.asarray([s.generated for s in sl], np.int32)
        maxnew = np.asarray([s.max_new for s in sl], np.int32)
        rx = self.retrieval
        if rx is not None:
            armed, arm_after = rx.fused_gates()
            trigger = (rx.rcfg.trigger, rx.rcfg.tau)
        else:
            armed = np.zeros((self.sc.n_slots,), bool)
            arm_after = np.zeros((self.sc.n_slots,), np.int32)
            trigger = None
        t0 = time.perf_counter()
        # extra=K: mid-window lengths grow up to K past the entry maximum,
        # and a page-table view is numerically neutral but a scatter
        # outside it would silently drop — the view must cover the window
        table = self._table_view(lengths, extra=K)
        if self.hetero is not None:
            res = self.hetero.decode_fused(
                self.params, self._pending, self.pool.device, table,
                lengths, live, K, gen_np=gen, maxnew_np=maxnew,
                armed_np=armed, arm_after_np=arm_after, trigger=trigger)
        else:
            res = self._decode_fused_inline(table, lengths, live, K, gen,
                                            maxnew, armed, arm_after,
                                            trigger)
        self.pool.device["k_pages"] = res["k_pages"]
        self.pool.device["v_pages"] = res["v_pages"]
        self._pending = np.asarray(res["pending"], np.int32).copy()
        nsteps = res["nsteps"]
        emits, fired = res["emits"], res["fired"]
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["host_steps"] += 1
        self.stats["decode_steps"] += nsteps
        ev = StepEvents(steps=nsteps)
        for j in range(nsteps):
            step_live = emits[j] >= 0
            for i in np.flatnonzero(step_live):
                ev.emissions.append((sl[i].request_id, int(i),
                                     int(emits[j, i])))
                if rx is not None:
                    rx.note_token(int(i), int(emits[j, i]))
            self.stats["tokens"] += int(step_live.sum())
            self.slots.step(step_live)
            for i in np.flatnonzero(step_live):
                if sl[i].done:
                    ev.finished.append(int(i))
                    self.pool.release(int(i))
                    if rx is not None:
                        rx.on_release(int(i))
            if rx is not None:
                rx.tick()
                for job in rx.collect_ready(min_age=1):
                    self._queue_splice(*job)
                for i in np.flatnonzero(fired[j]):
                    if not self._reserve_splice(int(i)):
                        rx.note_suppressed(int(i))
                        continue
                    rx.launch(int(i))
                    ev.fired.append(int(i))
        return ev

    # -- retrieval service hooks (src/repro/retrieval) ------------------

    def has_retrieval_work(self) -> bool:
        """True while a retrieval is in flight or a slot awaits its result
        (the scheduler must keep stepping an otherwise-idle pool)."""
        return self.retrieval is not None and self.retrieval.busy()

    def _retrieval_idle(self) -> None:
        """No decodable slot this step: still age + drain overlapped
        queries so paused slots get their splice queued."""
        rx = self.retrieval
        rx.tick()
        for job in rx.collect_ready(min_age=1):
            self._queue_splice(*job)

    def _retrieval_step(self, logits, live_np: np.ndarray,
                        lengths_np: np.ndarray) -> List[int]:
        """Post-decode retrieval phase: consume queries launched on earlier
        steps (the fired slot paused for exactly one step in EVERY mode —
        one dataflow, barriers differ), then evaluate this step's triggers,
        reserve pages, and launch. Returns the slots whose queries
        launched this step."""
        rx = self.retrieval
        rx.tick()
        for job in rx.collect_ready(min_age=1):
            self._queue_splice(*job)
        launched: List[int] = []
        for slot in rx.trigger_slots(logits, live_np, lengths_np,
                                     self.slots.slots):
            if not self._reserve_splice(slot):
                rx.note_suppressed(slot)
                continue
            rx.launch(slot)
            launched.append(slot)
        return launched

    def _reserve_splice(self, slot: int) -> bool:
        """Grow the slot's page reservation for the retrieval upper bound
        AT THE TRIGGER STEP, so pool accounting is schedule-independent."""
        s = self.slots.slots[slot]
        need = s.length + self.retrieval.splice_bound() + \
            (s.max_new - s.generated)
        if need > self.sc.max_len:
            return False
        return self.pool.grow(slot, need)

    def _queue_splice(self, slot: int, tokens, embeds, ids) -> None:
        """Push a retrieved payload into the chunked-extend queue; the slot
        rejoins decode once the splice drains, its pending token REGENERATED
        from the document-augmented context (FLARE semantics)."""
        payload = tokens if tokens is not None else embeds
        if payload is None or len(payload) == 0:
            return
        s = self.slots.slots[slot]
        self._chunks[slot] = [s.request_id, payload, 0,
                              embeds is not None]
        self.retrieval.note_splice(
            slot, tokens if tokens is not None else len(embeds))

    def _step_pool_dense(self) -> StepEvents:
        """Legacy baseline: dense pool, shared length watermark (max over
        slots) — every slot pays the longest sequence's attention cost and
        the sparse fallback cond sees the watermark, not true lengths."""
        live = self.slots.live_mask()
        if not live.any():
            return StepEvents()
        lengths = self.slots.lengths()
        self.caches = dict(self.caches,
                           length=jnp.asarray(lengths.max(), jnp.int32))
        tok = jnp.asarray(self._pending)
        logits, self.caches = self._decode(self.params, tok, self.caches,
                                           self.sparse_params)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        self.stats["host_steps"] += 1
        self.stats["decode_steps"] += 1
        ev = StepEvents(steps=1)
        for i in np.flatnonzero(live):
            rid = self.slots.slots[i].request_id
            ev.emissions.append((rid, int(i), int(self._pending[i])))
            self._pending[i] = nxt[i]
        self.slots.step(live)
        for i in np.flatnonzero(live):
            if self.slots.slots[i].done:
                ev.finished.append(int(i))
        return ev
