"""Batched decode engine with the memory pipeline as a first-class feature.

* builds jitted prefill / decode steps (optionally on separate role meshes —
  the paper's prefill/decode disaggregation, Fig. 6b),
* wires the sparse-attention memory pipeline into decode via the placement
  policy: a traced lax.cond implements the paper's DYNAMIC FALLBACK — dense
  attention below ``min_context`` and above ``fallback_context``, the fused
  sparse pipeline in between,
* supports continuous batching through SlotManager (dense/MoE/VLM/audio
  families; recurrent-state archs use the simple batched ``generate``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MemoryConfig
from repro.core import placement
from repro.core.methods import get_sparse_method
from repro.models import model as M
from repro.serving.kv_cache import SlotManager


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 4096
    n_slots: int = 8
    method: str = "none"       # none | dsa | seer | lserve
    tp: int = 16
    page: int = 16             # dsa micro-page size
    greedy: bool = True


class Engine:
    def __init__(self, cfg: ArchConfig, params, sc: ServeConfig,
                 key=None, mem: Optional[MemoryConfig] = None):
        self.cfg = cfg
        self.params = params
        self.mem = mem or cfg.memory.replace(method=sc.method)
        # the paged pipeline needs the cache length page-aligned
        gran = max(sc.page, self.mem.block_size,
                   self.mem.block_size * self.mem.pages_per_physical
                   if sc.method == "lserve" else 1)
        if sc.method != "none" and sc.max_len % gran:
            sc = dataclasses.replace(
                sc, max_len=((sc.max_len + gran - 1) // gran) * gran)
        self.sc = sc
        self.sparse_params = None
        sparse_fn = None
        if sc.method != "none" and cfg.family != "ssm":
            init_fn, mk = get_sparse_method(sc.method)
            self.sparse_params = init_fn(
                key if key is not None else jax.random.PRNGKey(0),
                cfg, self.mem, stacked=cfg.family != "hybrid")
            kw = {"page": sc.page} if sc.method == "dsa" else {}
            raw = mk(cfg, self.mem, tp=sc.tp, **kw)
            mem = self.mem

            def fallback_fn(q, kc, vc, length, sp, k_new=None):
                """Paper's dynamic fallback as a traced cond."""
                from repro.models import attention as A

                def dense(_):
                    return A.attention_decode(q, kc, vc, length, cfg, tp=sc.tp)

                def sparse(_):
                    return raw(q, kc, vc, length, sp, k_new=k_new)

                use_sparse = ((length >= mem.min_context) &
                              (length <= mem.fallback_context))
                return jax.lax.cond(use_sparse, sparse, dense, None)

            sparse_fn = fallback_fn
        self._sparse_fn = sparse_fn

        self._prefill = jax.jit(
            lambda p, toks: M.prefill(p, cfg, toks, max_len=sc.max_len,
                                      tp=sc.tp),
        )
        self._decode = jax.jit(
            lambda p, tok, caches, sp: M.decode_step(
                p, cfg, tok, caches, tp=sc.tp,
                sparse_fn=self._sparse_fn,
                sparse_params=sp),
        )
        self.slots = SlotManager(sc.n_slots, sc.max_len)
        self.caches = None
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0}

    # ------------------------------------------------------------------
    # simple batched API
    # ------------------------------------------------------------------

    def generate(self, prompts: jnp.ndarray, max_new: int) -> np.ndarray:
        """prompts [B, S] -> generated [B, max_new] (greedy)."""
        t0 = time.perf_counter()
        logits, caches = jax.block_until_ready(
            self._prefill(self.params, prompts))
        self.stats["prefill_s"] += time.perf_counter() - t0
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = []
        t0 = time.perf_counter()
        for _ in range(max_new):
            out.append(tok)
            logits, caches = self._decode(self.params, tok, caches,
                                          self.sparse_params)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["tokens"] += int(prompts.shape[0]) * max_new
        return np.stack([np.asarray(t) for t in out], axis=1)

    # ------------------------------------------------------------------
    # continuous batching (dense-cache families)
    # ------------------------------------------------------------------

    def _ensure_pool(self):
        if self.caches is None:
            self.caches = M.make_cache(self.cfg, self.sc.n_slots,
                                       self.sc.max_len, tp=self.sc.tp)
            self._pending = np.zeros((self.sc.n_slots,), np.int32)

    def admit(self, request_id: int, prompt: np.ndarray, max_new: int) -> bool:
        """Prefill one request into a free slot (insertion into the pool)."""
        assert self.cfg.family in ("dense", "moe", "audio", "vlm"), \
            "continuous batching requires dense KV caches"
        self._ensure_pool()
        slot = self.slots.admit(request_id, len(prompt), max_new)
        if slot is None:
            return False
        logits, c1 = self._prefill(self.params, jnp.asarray(prompt)[None])
        S = len(prompt)
        # splice the single-sequence cache into the pool at `slot`
        self.caches["k"] = jax.lax.dynamic_update_slice(
            self.caches["k"], c1["k"], (0, slot, 0, 0, 0))
        self.caches["v"] = jax.lax.dynamic_update_slice(
            self.caches["v"], c1["v"], (0, slot, 0, 0, 0))
        self._pending[slot] = int(jnp.argmax(logits[0]))
        return True

    def step_pool(self) -> List[Tuple[int, int, int]]:
        """One decode step for every live slot; returns (request_id, slot,
        token) emissions. NOTE: the pooled path tracks a shared `length`
        watermark (max over slots); per-slot masking handles shorter ones."""
        self._ensure_pool()
        live = self.slots.live_mask()
        if not live.any():
            return []
        lengths = self.slots.lengths()
        self.caches = dict(self.caches,
                           length=jnp.asarray(lengths.max(), jnp.int32))
        tok = jnp.asarray(self._pending)
        logits, self.caches = self._decode(self.params, tok, self.caches,
                                           self.sparse_params)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        out = []
        for i in np.flatnonzero(live):
            rid = self.slots.slots[i].request_id
            out.append((rid, int(i), int(self._pending[i])))
            self._pending[i] = nxt[i]
        self.slots.step(live)
        return out
