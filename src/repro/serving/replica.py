"""One fleet worker: an Engine pinned to a device group, plus a monitor.

An :class:`EngineReplica` wraps an :class:`~repro.serving.engine.Engine`
whose params (and therefore every jit dispatch) are committed to the first
device of the replica's group (``hetero.policy.pick_devices_replicas``);
the group's remaining devices serve that engine's offload/retrieval side.
The replica runs the engine's existing continuous-batching loop — one
``poll()`` per fleet turn drains its monitored admission queue, advances
chunked prefill, and runs one pooled-decode dispatch with fused windows
and hetero offload unchanged underneath.

The monitor samples queue depth and slot utilization at every poll — the
per-replica load signals the router routes by and the load harness
(benchmarks/bench_router.py) reports.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serving.api import Request, ResponseHandle
from repro.serving.engine import Engine, ServeConfig
from repro.serving.events import StepEvents


@dataclasses.dataclass
class ReplicaMonitor:
    """Per-poll samples of the admission queue and the slot pool."""

    queue_depth: List[int] = dataclasses.field(default_factory=list)
    live_slots: List[int] = dataclasses.field(default_factory=list)
    n_slots: int = 0
    polls: int = 0
    tokens: int = 0

    def sample(self, engine: Engine, emitted: int) -> None:
        self.polls += 1
        self.tokens += emitted
        self.queue_depth.append(engine.queue_depth())
        self.live_slots.append(int(engine.slots.live_mask().sum()))

    def utilization(self) -> float:
        """Mean fraction of slots decoding, over the polled lifetime."""
        if not self.live_slots or not self.n_slots:
            return 0.0
        return float(np.mean(self.live_slots)) / self.n_slots

    def as_dict(self) -> Dict:
        qd = self.queue_depth or [0]
        return {
            "polls": self.polls,
            "tokens": self.tokens,
            "utilization": self.utilization(),
            "queue_depth": {"mean": float(np.mean(qd)),
                            "max": int(np.max(qd))},
        }


class EngineReplica:
    def __init__(self, index: int, cfg, params, sc: ServeConfig, *,
                 key=None, mem=None, devices=None):
        self.index = index
        self.engine = Engine(cfg, params, sc, key=key, mem=mem,
                             devices=devices)
        self.monitor = ReplicaMonitor(n_slots=sc.n_slots)
        self.sessions = set()          # affinity keys pinned here

    @property
    def method(self) -> str:
        return self.engine.sc.method

    @property
    def devices(self):
        return self.engine.devices

    def load(self) -> int:
        """Queued + resident requests — the router's routing signal."""
        return self.engine.queue_depth() + len(self.engine._inflight_h)

    def busy(self) -> bool:
        return self.engine.busy()

    def can_serve(self, req: Request) -> bool:
        """Static eligibility: a per-request method override routes to a
        replica serving that sparse method; a retrieval opt-in needs the
        retrieval service configured."""
        want = req.override("method")
        if want is not None and want != self.method:
            return False
        if req.retrieval and self.engine.retrieval is None:
            return False
        return True

    def submit(self, req: Request) -> ResponseHandle:
        if req.session is not None:
            self.sessions.add(req.session)
        h = self.engine.submit(req)
        h.replica = self.index
        return h

    def poll(self) -> StepEvents:
        ev = self.engine.poll()
        self.monitor.sample(self.engine, len(ev.emissions))
        return ev

    def made_progress(self, ev: StepEvents) -> bool:
        """Did the last poll move this replica forward (or can the next)?"""
        return bool(ev.emissions) or self.engine._polled_prefill \
            or self.engine.has_prefill_work() \
            or self.engine.has_retrieval_work()

    def report(self) -> Dict:
        eng = self.engine
        out = {
            "replica": self.index,
            "method": self.method,
            "devices": [str(d) for d in (eng.devices or [])],
            "sessions": len(self.sessions),
            "done": len(eng.done),
            **self.monitor.as_dict(),
        }
        if eng.retrieval is not None:
            out["retrievals"] = len(eng.retrieval.events)
        return out
