"""Single-engine compatibility shim over the request-level serving API.

The continuous-batching logic this module used to own — FCFS admission
under a prefill token budget, chunked admission for long prompts, the
drain loop with its starvation brake — now lives INSIDE the engine behind
``Engine.submit(Request) -> ResponseHandle`` / ``poll()`` / ``drain()``
(serving/api.py), where the fleet router shares it. ``Scheduler`` remains
as the thin positional-prompt front the launchers and older tests grew up
with: it mints sequential rids, wraps prompts into :class:`Request`, and
proxies queue/inflight/done straight from the engine.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.serving.api import Request, ResponseHandle
from repro.serving.engine import Engine


class Scheduler:
    def __init__(self, engine: Engine, prefill_token_budget: int = 2048):
        self.engine = engine
        engine.prefill_token_budget = prefill_token_budget
        self._next_id = 0

    @property
    def prefill_token_budget(self) -> int:
        return self.engine.prefill_token_budget

    @property
    def queue(self):
        return self.engine.queue

    @property
    def inflight(self) -> Dict[int, ResponseHandle]:
        return self.engine._inflight_h

    @property
    def done(self) -> Dict[int, ResponseHandle]:
        return self.engine.done

    def submit(self, prompt: np.ndarray, max_new: int,
               retrieval: Optional[bool] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self.engine.submit(Request(rid, np.asarray(prompt), max_new,
                                   retrieval=retrieval))
        return rid

    def run(self, max_steps: int = 10_000) -> Dict[int, ResponseHandle]:
        """Drain the queue; returns completed requests by rid."""
        return self.engine.drain(max_steps)

    def throughput_tokens_per_s(self) -> float:
        return self.engine.throughput_tokens_per_s()
