"""Continuous-batching request scheduler (FCFS with admission control).

The engine's jitted decode step has a static batch (= slot count); the
scheduler's job is to keep those slots full: admit queued requests into free
slots, step the pooled decode, collect completions, and report utilization —
the serving-side counterpart of the paper's batch-scaling study (Table 4).

Admission no longer serializes under load: queued short prompts are admitted
TOGETHER (the engine buckets them by length and runs one pre-jitted prefill
per bucket), long prompts are admitted in chunked mode — their pages are
reserved up front and the prompt streams in ``prefill_chunk``-sized spans
interleaved with decode steps, bounded per step by ``prefill_token_budget``
so decode latency stays flat while prefill drains.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.serving.engine import Engine


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray
    max_new: int
    submitted: float = dataclasses.field(default_factory=time.perf_counter)
    tokens: List[int] = dataclasses.field(default_factory=list)
    finished: Optional[float] = None
    # retrieval-service opt-in/out (None = engine default when configured)
    retrieval: Optional[bool] = None


class Scheduler:
    def __init__(self, engine: Engine, prefill_token_budget: int = 2048):
        self.engine = engine
        self.prefill_token_budget = prefill_token_budget
        self.queue: collections.deque = collections.deque()
        self.inflight: Dict[int, Request] = {}
        self.done: Dict[int, Request] = {}
        self._next_id = 0

    def submit(self, prompt: np.ndarray, max_new: int,
               retrieval: Optional[bool] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(prompt), max_new,
                                  retrieval=retrieval))
        return rid

    def _admit(self):
        """FCFS batch admission within the per-step prefill token budget."""
        budget = self.prefill_token_budget
        batch: List[Request] = []
        chunked = self.engine.sc.paged
        while self.queue and budget > 0:
            req = self.queue[0]
            plen = len(req.prompt)
            if chunked and plen > self.engine.sc.chunk_threshold:
                # long prompt: reserve pages now, stream the prompt later
                if not self.engine.admit_chunked(req.request_id, req.prompt,
                                                 req.max_new,
                                                 retrieval=req.retrieval):
                    break
                self.queue.popleft()
                self.inflight[req.request_id] = req
                continue
            if batch and plen > budget:
                break                      # defer the rest to the next step
            batch.append(req)
            self.queue.popleft()
            budget -= plen
        if not batch:
            return
        oks = self.engine.admit_many(
            [(r.request_id, r.prompt, r.max_new) for r in batch],
            retrieval=[r.retrieval for r in batch])
        # re-queue rejections at the FRONT, preserving FCFS order
        for r, ok in zip(reversed(batch), reversed(oks)):
            if ok:
                self.inflight[r.request_id] = r
            else:
                self.queue.appendleft(r)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        """Drain the queue; returns completed requests."""
        steps = 0
        while (self.queue or self.inflight) and steps < max_steps:
            self._admit()
            prefilled = self.engine.has_prefill_work() and \
                self.engine.prefill_step()
            emissions = self.engine.step_pool()
            # a fused window consumes several device steps in one dispatch;
            # idle dispatches still count as one scheduler turn
            steps += max(1, getattr(emissions, "steps", 1))
            for rid, slot, tok in emissions:
                req = self.inflight.get(rid)
                if req is None:
                    continue
                req.tokens.append(tok)
                if len(req.tokens) >= req.max_new:
                    req.finished = time.perf_counter()
                    self.done[rid] = req
                    del self.inflight[rid]
            if not emissions and not prefilled:
                if self.engine.has_retrieval_work() or \
                        self.engine.has_prefill_work():
                    continue       # retrieval in flight, or a splice chunk
                                   # was queued DURING this step's decode
                if not self.queue:
                    break
                if not self.inflight:
                    break          # head request can never admit: stuck

        return self.done

    def throughput_tokens_per_s(self) -> float:
        toks = sum(len(r.tokens) for r in self.done.values())
        if not self.done:
            return 0.0
        t0 = min(r.submitted for r in self.done.values())
        t1 = max(r.finished for r in self.done.values())
        return toks / max(t1 - t0, 1e-9)
