"""Continuous-batching request scheduler (FCFS with admission control).

The engine's jitted decode step has a static batch (= slot count); the
scheduler's job is to keep those slots full: admit queued requests into free
slots (prefill), step the pooled decode, collect completions, and report
utilization — the serving-side counterpart of the paper's batch-scaling
study (Table 4).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.serving.engine import Engine


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray
    max_new: int
    submitted: float = dataclasses.field(default_factory=time.perf_counter)
    tokens: List[int] = dataclasses.field(default_factory=list)
    finished: Optional[float] = None


class Scheduler:
    def __init__(self, engine: Engine):
        self.engine = engine
        self.queue: collections.deque = collections.deque()
        self.inflight: Dict[int, Request] = {}
        self.done: Dict[int, Request] = {}
        self._next_id = 0

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(prompt), max_new))
        return rid

    def _admit(self):
        while self.queue:
            req = self.queue[0]
            if not self.engine.admit(req.request_id, req.prompt, req.max_new):
                break
            self.queue.popleft()
            self.inflight[req.request_id] = req

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        """Drain the queue; returns completed requests."""
        steps = 0
        while (self.queue or self.inflight) and steps < max_steps:
            self._admit()
            emissions = self.engine.step_pool()
            steps += 1
            for rid, slot, tok in emissions:
                req = self.inflight.get(rid)
                if req is None:
                    continue
                req.tokens.append(tok)
                if len(req.tokens) >= req.max_new:
                    req.finished = time.perf_counter()
                    self.done[rid] = req
                    del self.inflight[rid]
            if not emissions and not self.queue:
                break
        return self.done

    def throughput_tokens_per_s(self) -> float:
        toks = sum(len(r.tokens) for r in self.done.values())
        if not self.done:
            return 0.0
        t0 = min(r.submitted for r in self.done.values())
        t1 = max(r.finished for r in self.done.values())
        return toks / max(t1 - t0, 1e-9)
