"""Request-level serving API — the ONE admission surface of the engine.

Every way into the serving stack (single engine, compatibility scheduler,
fleet router) admits work as a frozen :class:`Request` through
``Engine.submit`` and reads results back through the :class:`ResponseHandle`
the submit returned. The positional ``(request_id, prompt, max_new)`` tuple
plumbing that used to thread through tests, scheduler and engine is gone —
the tuple layout was an implementation detail of the old batched-admit call
and every caller re-invented timing/stream bookkeeping around it.

``Request`` is immutable (it may sit in an admission queue, be re-queued at
the front after a rejection, or be routed between replicas — nobody gets to
mutate it in flight). ``ResponseHandle`` is the mutable side: the engine
appends tokens as they are emitted and stamps the timing fields the serving
benchmarks report (TTFT, per-token latency).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional

import numpy as np

#: recognised ``Request.method_overrides`` keys.
#:   chunked  force chunked admission on (True) / off (False) regardless of
#:            the ``chunk_threshold`` length heuristic
#:   method   route to a replica serving this sparse method (router-level;
#:            a single engine ignores it)
METHOD_OVERRIDE_KEYS = ("chunked", "method")


@dataclasses.dataclass(frozen=True, eq=False)
class Request:
    """One generation request.

    rid               caller-chosen id; unique among requests concurrently
                      known to the engine/router it is submitted to.
    tokens            prompt token ids (any int sequence; stored int32).
    max_new           tokens to generate (greedy).
    retrieval         opt the request in/out of the engine's retrieval
                      service (None = service default: on when configured).
    method_overrides  per-request knobs, see ``METHOD_OVERRIDE_KEYS``.
    session           affinity key: the router keeps every request of one
                      session on one replica (KV/retrieval locality).
    """

    rid: int
    tokens: np.ndarray
    max_new: int
    retrieval: Optional[bool] = None
    method_overrides: Optional[Mapping[str, Any]] = None
    session: Optional[Any] = None

    def __post_init__(self):
        toks = np.asarray(self.tokens, np.int32)
        if toks.ndim != 1:
            raise ValueError(f"Request.tokens must be 1-D, got {toks.shape}")
        toks.setflags(write=False)
        object.__setattr__(self, "tokens", toks)
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        if self.method_overrides is not None:
            mo = dict(self.method_overrides)
            bad = set(mo) - set(METHOD_OVERRIDE_KEYS)
            if bad:
                raise ValueError(
                    f"unknown method_overrides {sorted(bad)}; "
                    f"known: {METHOD_OVERRIDE_KEYS}")
            object.__setattr__(self, "method_overrides", mo)

    def override(self, key: str, default=None):
        if self.method_overrides is None:
            return default
        return self.method_overrides.get(key, default)

    def __len__(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class ResponseHandle:
    """Live view of one submitted request: the growing token stream plus the
    timing marks serving metrics are made of. Engine-owned fields are
    written by ``Engine.poll``; callers read."""

    request: Request
    submitted: float = dataclasses.field(default_factory=time.perf_counter)
    tokens: List[int] = dataclasses.field(default_factory=list)
    admitted: Optional[float] = None       # left the queue, entered a slot
    first_token_t: Optional[float] = None  # first emission surfaced
    finished: Optional[float] = None       # max_new tokens emitted
    replica: Optional[int] = None          # router: replica index served on

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def done(self) -> bool:
        return self.finished is not None

    @property
    def text(self) -> str:
        """Final text. The repo serves synthetic token streams (there is no
        tokenizer); the canonical detokenization is space-joined ids."""
        return " ".join(str(t) for t in self.tokens)

    def ttft_s(self) -> Optional[float]:
        """Submit -> first token (queueing + admission prefill + 1 step)."""
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submitted

    def per_token_s(self) -> Optional[float]:
        """Mean inter-token latency over the decode tail."""
        if not self.done or len(self.tokens) < 2:
            return None
        return (self.finished - self.first_token_t) / (len(self.tokens) - 1)

    def result(self) -> np.ndarray:
        assert self.done, f"request {self.rid} still in flight"
        return np.asarray(self.tokens, np.int32)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "rid": self.rid, "n_tokens": len(self.tokens),
            "ttft_s": self.ttft_s(), "per_token_s": self.per_token_s(),
            "replica": self.replica, "done": self.done,
        }
