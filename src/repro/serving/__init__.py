"""Serving stack: paged continuous batching behind a request-level API.

The curated surface — examples, benchmarks and the README import from
``repro.serving``, not deep module paths:

  Engine / ServeConfig / OffloadConfig   the pooled decode engine and its
                                         config (offload topology nested)
  Request / ResponseHandle               the ONE admission path
                                         (``Engine.submit``) and its live
                                         result view
  Router / EngineReplica                 fleet serving: a stateless router
                                         over device-pinned replicas
  Scheduler                              single-engine compatibility shim
                                         (positional prompts -> Requests)
  StepEvents                             typed result of one serving turn
  SlotManager / PagedKVPool              slot + paged-KV bookkeeping
"""
from repro.serving.api import Request, ResponseHandle
from repro.serving.engine import Engine, OffloadConfig, ServeConfig
from repro.serving.events import StepEvents
from repro.serving.kv_cache import PagedKVPool, SlotManager
from repro.serving.replica import EngineReplica
from repro.serving.router import Router
from repro.serving.scheduler import Scheduler

__all__ = [
    "Engine",
    "EngineReplica",
    "OffloadConfig",
    "PagedKVPool",
    "Request",
    "ResponseHandle",
    "Router",
    "Scheduler",
    "ServeConfig",
    "SlotManager",
    "StepEvents",
]
