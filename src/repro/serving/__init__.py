from repro.serving.engine import Engine, OffloadConfig, ServeConfig
from repro.serving.events import StepEvents
from repro.serving.scheduler import Scheduler, Request
from repro.serving.kv_cache import SlotManager, PagedKVPool
