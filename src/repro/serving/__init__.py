from repro.serving.engine import Engine, ServeConfig
from repro.serving.scheduler import Scheduler, Request
from repro.serving.kv_cache import SlotManager, PagedKVPool
