"""Slot-based KV cache manager for continuous batching.

The engine owns a fixed pool of ``n_slots`` sequences x ``max_len`` tokens
(the model-side caches are the dense arrays from models.make_cache, batch dim
= n_slots). This manager tracks slot liveness, per-slot lengths, admission,
and release — the host-side bookkeeping that turns a static-shape jitted
decode step into a continuous-batching server.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Slot:
    request_id: Optional[int] = None
    length: int = 0
    generated: int = 0
    max_new: int = 0
    done: bool = True


class SlotManager:
    def __init__(self, n_slots: int, max_len: int):
        self.n_slots = n_slots
        self.max_len = max_len
        self.slots: List[Slot] = [Slot() for _ in range(n_slots)]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.done]

    def admit(self, request_id: int, prompt_len: int, max_new: int) -> Optional[int]:
        free = self.free_slots()
        if not free or prompt_len + max_new > self.max_len:
            return None
        i = free[0]
        self.slots[i] = Slot(request_id, prompt_len, 0, max_new, False)
        return i

    def step(self, live_mask: np.ndarray):
        """Advance all live slots by one generated token."""
        for i, s in enumerate(self.slots):
            if not s.done and live_mask[i]:
                s.length += 1
                s.generated += 1
                if s.generated >= s.max_new or s.length >= self.max_len:
                    s.done = True

    def live_mask(self) -> np.ndarray:
        return np.asarray([not s.done for s in self.slots])

    def lengths(self) -> np.ndarray:
        return np.asarray([s.length for s in self.slots], np.int32)

    def utilization(self) -> float:
        return 1.0 - len(self.free_slots()) / self.n_slots
