"""Slot + page managers for continuous batching.

``SlotManager`` tracks slot liveness, per-slot lengths, admission, and
release — the host-side bookkeeping that turns a static-shape jitted decode
step into a continuous-batching server.

``PagedKVPool`` is the host-side allocator for the paged KV pool: a shared
arena of fixed-size physical pages (device arrays built by
``models.make_page_pool``) addressed through per-slot page tables. Slots
reserve ``ceil((prompt + max_new) / page_size)`` pages at admission and give
them back at release, so HBM scales with the tokens actually in flight
instead of ``n_slots * max_len``, and the pool can be oversubscribed
(``total_pages`` smaller than full backing) — admission simply waits when no
pages are free. Physical page 0 is reserved as the permanent zero page:
unallocated page-table entries point at it and freed pages are scrubbed back
to zero, which is what makes pooled decode bit-match per-request decode.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Slot:
    request_id: Optional[int] = None
    length: int = 0
    generated: int = 0
    max_new: int = 0
    done: bool = True


class SlotManager:
    def __init__(self, n_slots: int, max_len: int):
        self.n_slots = n_slots
        self.max_len = max_len
        self.slots: List[Slot] = [Slot() for _ in range(n_slots)]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.done]

    def admit(self, request_id: int, prompt_len: int, max_new: int) -> Optional[int]:
        free = self.free_slots()
        if not free or prompt_len + max_new > self.max_len:
            return None
        i = free[0]
        self.slots[i] = Slot(request_id, prompt_len, 0, max_new, False)
        return i

    def step(self, live_mask: np.ndarray):
        """Advance all live slots by one generated token."""
        for i, s in enumerate(self.slots):
            if not s.done and live_mask[i]:
                s.length += 1
                s.generated += 1
                if s.generated >= s.max_new or s.length >= self.max_len:
                    s.done = True

    def live_mask(self) -> np.ndarray:
        return np.asarray([not s.done for s in self.slots])

    def lengths(self) -> np.ndarray:
        return np.asarray([s.length for s in self.slots], np.int32)

    def utilization(self) -> float:
        return 1.0 - len(self.free_slots()) / self.n_slots


class PagedKVPool:
    """Host-side page allocator over the device arrays of a paged KV pool.

    The device side (``models.make_page_pool``) is a dict
    ``{k_pages, v_pages, page_table, lengths}``; this class owns the free
    list and the authoritative host page table, and hands the engine a
    device view to thread through the jitted decode/extend steps.
    """

    def __init__(self, cfg, n_slots: int, max_len: int, *,
                 page_size: int = 16, total_pages: int = 0, tp: int = 16):
        from repro.models import model as M

        assert max_len % page_size == 0, (max_len, page_size)
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_slot = max_len // page_size
        # +1 for the reserved zero page 0; 0 -> full backing (no
        # oversubscription), otherwise the caller picks the arena size.
        full = n_slots * self.pages_per_slot + 1
        self.total_pages = total_pages or full
        assert self.total_pages >= 2, "need at least one allocatable page"
        self.device = M.make_page_pool(cfg, n_slots, max_len,
                                       page_size=page_size,
                                       total_pages=self.total_pages, tp=tp)
        self.table = np.zeros((n_slots, self.pages_per_slot), np.int32)
        self.owned: List[List[int]] = [[] for _ in range(n_slots)]
        # LIFO free list; page 0 is never handed out
        self.free: List[int] = list(range(self.total_pages - 1, 0, -1))
        # monotonically bumped on every host-table push; the engine keys
        # its sliced table-view cache on it (alloc/grow/release are the
        # only events that change what a view slice contains)
        self.table_version = 0
        # freed pages must be scrubbed before reuse so the pool stays zero
        # outside live regions; pad to a fixed count to keep one jit.
        # Donated: release() replaces the device references with the outputs.
        self._zero_pages = jax.jit(
            lambda kp, vp, idx: (kp.at[:, idx].set(0.0),
                                 vp.at[:, idx].set(0.0)),
            donate_argnums=(0, 1))

    # -- allocation ----------------------------------------------------

    def pages_needed(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def can_alloc(self, n_tokens: int) -> bool:
        return self.pages_needed(n_tokens) <= len(self.free)

    def alloc(self, slot: int, n_tokens: int) -> bool:
        """Reserve pages for ``n_tokens`` logical tokens in ``slot``."""
        need = self.pages_needed(n_tokens)
        if need > len(self.free) or need > self.pages_per_slot:
            return False
        assert not self.owned[slot], f"slot {slot} already holds pages"
        got = [self.free.pop() for _ in range(need)]
        self.owned[slot] = got
        self.table[slot, :need] = got
        self._push_table()
        return True

    def grow(self, slot: int, n_tokens: int) -> bool:
        """Extend a LIVE slot's reservation to cover ``n_tokens`` logical
        tokens (dynamic-retrieval splice: the slot needs room for the
        retrieved documents on top of its admission-time reservation).
        Existing pages are kept; False when the arena or the per-slot page
        table cannot take the growth."""
        need = self.pages_needed(n_tokens)
        have = len(self.owned[slot])
        assert have, f"slot {slot} holds no pages"
        extra = need - have
        if extra <= 0:
            return True
        if extra > len(self.free) or need > self.pages_per_slot:
            return False
        got = [self.free.pop() for _ in range(extra)]
        self.owned[slot].extend(got)
        self.table[slot, have:need] = got
        self._push_table()
        return True

    def release(self, slot: int) -> None:
        """Return a slot's pages to the free list and scrub them to zero."""
        got = self.owned[slot]
        if not got:
            return
        # pad with the zero page (re-zeroing it is a no-op) for a static jit
        idx = np.zeros((self.pages_per_slot,), np.int32)
        idx[: len(got)] = got
        kp, vp = self._zero_pages(self.device["k_pages"],
                                  self.device["v_pages"], jnp.asarray(idx))
        self.device["k_pages"], self.device["v_pages"] = kp, vp
        self.free.extend(reversed(got))
        self.owned[slot] = []
        self.table[slot] = 0
        self._push_table()

    # -- views / stats -------------------------------------------------

    def _push_table(self) -> None:
        self.device["page_table"] = jnp.asarray(self.table)
        self.table_version += 1

    def shard_owners(self, n_shards: int) -> np.ndarray:
        """Logical page -> owning offload shard, [pages_per_slot].

        The sharded hetero executor cuts the logical token space into
        ``n_shards`` contiguous windows; logical page ``p`` of every slot
        belongs to shard ``p // (pages_per_slot // n_shards)``. This is the
        authoritative page->shard map the executor's static ingest windows
        must agree with (tests assert the correspondence), and what routes
        a splice / chunked extend to the owning shard's index."""
        assert self.pages_per_slot % n_shards == 0, \
            (self.pages_per_slot, n_shards)
        return np.repeat(np.arange(n_shards),
                         self.pages_per_slot // n_shards)

    def shard_table_view(self, n_shards: int, shard: int) -> np.ndarray:
        """The slice of every slot's page table owned by ``shard``:
        [n_slots, pages_per_slot // n_shards] physical page ids (0 = the
        reserved zero page for unallocated entries, which scores exactly
        like dead context on the shard's summary)."""
        own = self.shard_owners(n_shards) == shard
        return self.table[:, own]

    def pages_in_use(self) -> int:
        return sum(len(o) for o in self.owned)

    def n_free(self) -> int:
        return len(self.free)

    def tokens_capacity(self) -> int:
        return (self.total_pages - 1) * self.page_size
