"""Fused multi-step decode: K device steps per host dispatch (lax.scan).

The serving loop used to pay one full Python round-trip per decoded token —
launch decode, pull logits, argmax on host, update the slot table, launch
again. This module folds K steps into ONE jitted ``lax.scan``: decode,
greedy sampling, pool write/rotate, the hetero lookahead double-buffer
(select_{t+1} queued from the pre-ingest state while apply_t runs — the
ping-pong ``hetero/executor.py`` orchestrates from Python, here expressed
as carry state), and the FLARE/DRAGIN trigger predicate — all on device.

Early exit is masked, not structural: the scan body wraps in
``lax.cond(stop, idle, step)``; once any slot finishes or fires a trigger
the remaining iterations are no-ops and ``nsteps`` reports how many steps
were actually consumed. The host replays the emitted event log (per-step
emissions + fired flags) through the exact bookkeeping the stepped path
runs, so ``fused(K)`` emits token-for-token what K separate ``step_pool()``
calls emit:

  * per-step lengths are re-masked inside the body, so dead rows behave
    exactly as in the stepped path (their writes route to the zero page);
  * the dynamic-fallback window is the same traced predicate the apply
    phase uses (``placement.traced_use_sparse``), evaluated per step on the
    in-carry lengths — a window can cross ``min_context`` mid-scan and the
    selection double-buffer cold-starts on re-entry exactly like the host
    executor does;
  * the page-table view is sized with ``extra=K`` headroom (the engine's
    job): a view is numerically neutral (masked attention, exp(-1e30)=0
    exactly) but a scatter outside it would silently drop, so the window
    must cover the maximum mid-window length.

Host-visible semantics (finished slots, retrieval launches, splices,
admissions) stay host-side: the engine only enters a fused window when the
retrieval subsystem is quiescent and no chunked prefill is pending, and the
window exits back to the host at the first step that needs servicing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import placement
from repro.models import model as M


def _blend_q(q_buf, q_t, live):
    """Stale-query refresh (``HeteroExecutor._blend_q`` with a live mask):
    rows that decoded this step take the new query."""
    return jnp.where(live[None, :, None, None], q_t.astype(q_buf.dtype),
                     q_buf)


def _advance(c, logits, lengths_m, maxnew, max_len, armed, arm_after,
             trigger):
    """Shared post-decode bookkeeping of one in-scan step: greedy sampling,
    emission, slot advance, finish detection, trigger predicate, stop flag.
    Mirrors ``slots.step`` + ``_retrieval_step`` bit for bit."""
    live = c["live"]
    adv = live.astype(jnp.int32)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    emit = jnp.where(live, c["pending"], -1)
    pending = jnp.where(live, nxt, c["pending"])
    gen = c["gen"] + adv
    emitted = c["emitted"] + adv
    lengths = c["lengths"] + adv
    fin = live & ((gen >= maxnew) | (lengths >= max_len))
    if trigger is None:
        fired = jnp.zeros_like(live)
    else:
        from repro.retrieval.executor import traced_trigger
        pred = traced_trigger(trigger[0], trigger[1], logits, lengths_m)
        # the host gates (enabled, budget, cooldown, bank occupancy) are
        # static or countdown-expressible over the window: ``armed`` folds
        # the static ones, ``arm_after`` is the emitted-token count at
        # which the countdown gates open (hist grows 1/emitted token)
        fired = pred & live & ~fin & armed & (emitted >= arm_after)
    stop = fin.any() | fired.any()
    c = dict(c, pending=pending, gen=gen, emitted=emitted, lengths=lengths,
             live=live & ~fin, stop=stop, nsteps=c["nsteps"] + 1)
    return c, (emit, fired)


def make_fused_paged(cfg, mem, sc, *, K: int, trigger, sparse_fn):
    """Fused loop for the INLINE pipeline (``offload='off'``): K iterations
    of ``decode_step_paged`` (sparse method + dynamic fallback fused inside
    ``sparse_fn``) with sampling and trigger checks on device.

    Returns an unjitted ``fn(params, sp, tok, kp, vp, table, lengths, live,
    gen, maxnew, armed, arm_after) -> outs``; the engine jits it with
    the pool buffers donated."""

    def fused(params, sp, tok, kp, vp, table, lengths, live, gen, maxnew,
              armed, arm_after):
        B = tok.shape[0]

        def idle(c):
            return c, (jnp.full((B,), -1, jnp.int32),
                       jnp.zeros((B,), bool))

        def step(c):
            lengths_m = jnp.where(c["live"], c["lengths"], 0)
            pool = {"k_pages": c["kp"], "v_pages": c["vp"],
                    "page_table": table, "lengths": lengths_m}
            logits, pool = M.decode_step_paged(
                params, cfg, c["pending"], pool, c["live"], tp=sc.tp,
                sparse_fn=sparse_fn, sparse_params=sp)
            c = dict(c, kp=pool["k_pages"], vp=pool["v_pages"])
            return _advance(c, logits, lengths_m, maxnew, sc.max_len,
                            armed, arm_after, trigger)

        def body(c, _):
            return jax.lax.cond(c["stop"], idle, step, c)

        carry = {"kp": kp, "vp": vp, "pending": tok,
                 "lengths": lengths.astype(jnp.int32), "live": live,
                 "gen": gen, "emitted": jnp.zeros_like(gen),
                 "stop": jnp.zeros((), bool),
                 "nsteps": jnp.zeros((), jnp.int32)}
        carry, (emits, fired) = jax.lax.scan(body, carry, None, length=K)
        return {"k_pages": carry["kp"], "v_pages": carry["vp"],
                "pending": carry["pending"], "nsteps": carry["nsteps"],
                "emits": emits, "fired": fired}

    return fused


def make_fused_presel(cfg, mem, sc, sel, *, K: int, trigger, page_attn):
    """Fused loop for the HETERO two-phase pipeline: apply over preselected
    pages + the on-device selection double-buffer.

    Per iteration, from the carry's (summary, qbuf, sel, sel_ok):

      consume   pidx = pending lookahead if sel_ok, else a cold-start
                select from the pre-ingest carry state (matching the host
                executor's cold path after a fallback step);
      lookahead nxt_sel = select(summary_pre, qbuf_pre, lengths + live) —
                the exact inputs ``_launch_select(lengths_np + live_np)``
                pins in the stepped schedule;
      apply     ``decode_step_paged_presel`` (scan-compatible carry: pool
                lengths re-masked per step, this step's per-layer q/k out);
      ingest    fold q/k into summary/qbuf for the next iteration.

    The final (sel, sel_ok) and the PRE-ingest pins of the last executed
    step come back to the host so the executor can resume its stepped
    double-buffer (and ``validate=True`` can replay the exit lookahead)
    without a cold start. Sharded executors pass the full-window summary
    (shard summaries concatenated along the page axis — bit-identical to
    the merged per-shard selection) and scatter it back after the window.
    """

    def fused(params, sp, tok, kp, vp, table, lengths, live, gen, maxnew,
              sel0, sel_ok0, summary0, qbuf0, armed, arm_after):
        B = tok.shape[0]
        neg = jnp.full((cfg.n_layers, B, sel.n_sel), -1, jnp.int32)

        def idle(c):
            return c, (jnp.full((B,), -1, jnp.int32),
                       jnp.zeros((B,), bool), jnp.zeros((), bool))

        def step(c):
            lengths_m = jnp.where(c["live"], c["lengths"], 0)
            # same predicate as the apply phase's internal cond AND the
            # host executor's dynamic_mode mirror
            offl = placement.traced_use_sparse(lengths_m + 1, mem)
            pidx = jax.lax.cond(
                offl,
                lambda _: jax.lax.cond(
                    c["sel_ok"], lambda _: c["sel"],
                    lambda _: sel.select(sp, c["summary"], c["qbuf"],
                                         lengths_m), None),
                lambda _: neg, None)
            la_len = lengths_m + c["live"].astype(jnp.int32)
            nxt_sel = jax.lax.cond(
                offl,
                lambda _: sel.select(sp, c["summary"], c["qbuf"], la_len),
                lambda _: c["sel"], None)
            pool = {"k_pages": c["kp"], "v_pages": c["vp"],
                    "page_table": table, "lengths": lengths_m}
            logits, pool, q_t, k_t = M.decode_step_paged_presel(
                params, cfg, c["pending"], pool, c["live"], pidx, mem,
                page_size=sel.page, tp=sc.tp, page_attn=page_attn)
            c = dict(c, kp=pool["k_pages"], vp=pool["v_pages"],
                     # pre-ingest pins of THIS step: the inputs the exit
                     # lookahead was computed from (validation replay)
                     prev_summary=c["summary"], prev_q=c["qbuf"],
                     prev_len=la_len,
                     summary=sel.ingest(c["summary"], sp, k_t, lengths_m,
                                        c["live"]),
                     qbuf=_blend_q(c["qbuf"], q_t, c["live"]),
                     sel=nxt_sel, sel_ok=offl)
            c, (emit, fired) = _advance(c, logits, lengths_m, maxnew,
                                        sc.max_len, armed, arm_after,
                                        trigger)
            return c, (emit, fired, offl)

        def body(c, _):
            return jax.lax.cond(c["stop"], idle, step, c)

        carry = {"kp": kp, "vp": vp, "pending": tok,
                 "lengths": lengths.astype(jnp.int32), "live": live,
                 "gen": gen, "emitted": jnp.zeros_like(gen),
                 "sel": sel0, "sel_ok": sel_ok0,
                 "summary": summary0, "qbuf": qbuf0,
                 "prev_summary": summary0, "prev_q": qbuf0,
                 "prev_len": lengths.astype(jnp.int32),
                 "stop": jnp.zeros((), bool),
                 "nsteps": jnp.zeros((), jnp.int32)}
        carry, (emits, fired, offl) = jax.lax.scan(body, carry, None,
                                                   length=K)
        return {"k_pages": carry["kp"], "v_pages": carry["vp"],
                "pending": carry["pending"], "nsteps": carry["nsteps"],
                "sel": carry["sel"], "sel_ok": carry["sel_ok"],
                "summary": carry["summary"], "qbuf": carry["qbuf"],
                "prev_summary": carry["prev_summary"],
                "prev_q": carry["prev_q"], "prev_len": carry["prev_len"],
                "emits": emits, "fired": fired, "offl": offl}

    return fused
