"""Typed stepping result of ``Engine.step_pool`` (legacy AND fused paths).

``step_pool`` used to return a bare ``List[Tuple[request_id, slot, token]]``;
with the fused multi-step decode loop one host call can consume several
device steps, finish slots, and fire retrieval triggers — the caller needs
all of that, not just the token tuples. ``StepEvents`` carries:

  emissions  [(request_id, slot, token)] in step-major order (the exact
             sequence K separate ``step_pool()`` calls would have emitted);
  finished   slots released during the call (their pages are already back
             on the free list);
  fired      slots whose FLARE/DRAGIN trigger fired (retrieval launched or
             suppressed — either way the slot charged its cooldown);
  steps      device decode steps consumed (1 for the legacy path, up to
             ``ServeConfig.fused_steps`` for the fused path).

Tuple-style access (``for rid, slot, tok in engine.step_pool()``) keeps
working through ``__iter__``/``__len__``/``__getitem__`` — the deprecation
shim for one release while callers migrate to the named fields.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple


@dataclasses.dataclass
class StepEvents:
    emissions: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)
    finished: List[int] = dataclasses.field(default_factory=list)
    fired: List[int] = dataclasses.field(default_factory=list)
    steps: int = 0

    # -- legacy list-of-tuples shim ------------------------------------

    def __iter__(self) -> Iterator[Tuple[int, int, int]]:
        return iter(self.emissions)

    def __len__(self) -> int:
        return len(self.emissions)

    def __bool__(self) -> bool:
        return bool(self.emissions)

    def __getitem__(self, i):
        return self.emissions[i]
