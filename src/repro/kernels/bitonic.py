"""Bitonic sort primitive usable INSIDE Pallas TPU kernels.

Mosaic has no ``lax.sort``/``lax.top_k`` lowering, so the fused
relevancy+retrieval kernels sort with a bitonic compare-exchange network
built purely from reshapes + ``jnp.where`` (the partner element ``x[i ^ j]``
for power-of-two ``j`` is a swap of one reshaped axis — no gathers).

Ties are broken lexicographically on the integer payload (ascending index),
which makes the network a strict total order — exchanges stay consistent and
no payload is ever duplicated or dropped.

This mirrors the paper's FPGA "parallel reduction tree" top-k retriever
(Fig. 7b): same O(n log^2 n) compare network, vectorized over VPU lanes
instead of unrolled into LUTs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _partner_swap(x: jnp.ndarray, j: int) -> jnp.ndarray:
    """Return y with y[..., i] = x[..., i ^ j] (j a power of two)."""
    n = x.shape[-1]
    lead = x.shape[:-1]
    y = x.reshape(lead + (n // (2 * j), 2, j))
    y = jnp.flip(y, axis=-2)
    return y.reshape(lead + (n,))


def _bit_pattern(n: int, bit: int) -> jnp.ndarray:
    """Boolean [n]: True where (i & bit) == 0.

    Built from lax.iota (not a numpy constant) so the expression is legal
    inside a pallas_call kernel body — Pallas rejects captured constants.
    """
    i = jax.lax.iota(jnp.int32, n)
    return (i & bit) == 0


def bitonic_sort_desc(keys: jnp.ndarray, vals: jnp.ndarray):
    """Sort descending along the last axis. keys fp, vals int payload.

    Shapes [..., n] with n a power of two. Returns (keys_sorted, vals_sorted).
    """
    n = keys.shape[-1]
    assert n & (n - 1) == 0, f"bitonic sort needs power-of-two n, got {n}"
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            pk = _partner_swap(keys, j)
            pv = _partner_swap(vals, j)
            # runs with (i & k) == 0 sort DESCENDING (for i < n=k this covers
            # the whole array, giving a descending final merge)
            desc = _bit_pattern(n, k)
            is_lower = _bit_pattern(n, j)
            # descending run: lower index of the pair takes the max
            take_max = ~jnp.logical_xor(desc, is_lower)
            # strict self-wins predicate (lexicographic on (key, -val))
            self_gt = (keys > pk) | ((keys == pk) & (vals < pv))
            sel_self = jnp.where(take_max, self_gt, ~self_gt)
            keys = jnp.where(sel_self, keys, pk)
            vals = jnp.where(sel_self, vals, pv)
            j //= 2
        k *= 2
    return keys, vals


def bitonic_topk(keys: jnp.ndarray, vals: jnp.ndarray, k: int):
    """Top-k by full descending sort + slice (exact when k <= n)."""
    ks, vs = bitonic_sort_desc(keys, vals)
    return ks[..., :k], vs[..., :k]
