"""Pure-jnp oracles for every Pallas kernel (the source of truth in tests).

All oracles use fp32 math and XLA-native ops (lax.top_k, einsum, softmax).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# 1. Fused relevancy scoring + top-k (DeepSeek lightning-indexer style)
# ---------------------------------------------------------------------------


def relevancy_scores(q: jnp.ndarray, keys: jnp.ndarray,
                     weights: jnp.ndarray) -> jnp.ndarray:
    """q [B,Hq,dk]; keys [B,S,dk]; weights [B,Hq] -> scores [B,S].

    score_s = sum_h w_h * relu(q_h . k_s)   (DSA indexer, paper App. D)
    """
    dots = jnp.einsum("bhd,bsd->bhs", q.astype(jnp.float32),
                      keys.astype(jnp.float32))
    return jnp.einsum("bh,bhs->bs", weights.astype(jnp.float32),
                      jax.nn.relu(dots))


def relevancy_topk(q, keys, weights, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact oracle: (vals [B,k], idx [B,k]) sorted descending.

    ``k`` is clamped to the key count, matching the fused kernel path
    (ops.relevancy_topk passes ``min(k, S)`` to the candidate merge)."""
    scores = relevancy_scores(q, keys, weights)
    return jax.lax.top_k(scores, min(k, keys.shape[1]))


# ---------------------------------------------------------------------------
# 2. Paged sparse decode attention (apply-to-inference stage)
# ---------------------------------------------------------------------------


def paged_decode_attention(
    q: jnp.ndarray,            # [B, Hq, dh]
    k_cache: jnp.ndarray,      # [B, S, KV, dh]
    v_cache: jnp.ndarray,      # [B, S, KV, dh]
    page_ids: jnp.ndarray,     # [B, P] int32, -1 = invalid
    page_size: int,
    length,                    # [] or [B]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Attention of one query over the selected pages -> (out [B,Hq,dh],
    lse [B,Hq])."""
    B, S, KV, dh = k_cache.shape
    Hq = q.shape[1]
    G = Hq // KV
    P = page_ids.shape[1]
    ps = page_size
    safe = jnp.maximum(page_ids, 0)
    # gather pages: [B, P, ps, KV, dh]
    kp = k_cache.reshape(B, S // ps, ps, KV, dh)
    vp = v_cache.reshape(B, S // ps, ps, KV, dh)
    kg = jnp.take_along_axis(kp, safe[:, :, None, None, None], axis=1)
    vg = jnp.take_along_axis(vp, safe[:, :, None, None, None], axis=1)
    qg = q.reshape(B, KV, G, dh).astype(jnp.float32) / np.sqrt(dh)
    sc = jnp.einsum("bkgd,bptkd->bkgpt", qg, kg.astype(jnp.float32))
    tok_pos = safe[:, :, None] * ps + jnp.arange(ps)[None, None, :]  # [B,P,ps]
    length = jnp.asarray(length)
    lb = length if length.ndim else jnp.broadcast_to(length, (B,))
    valid = (page_ids[:, :, None] >= 0) & (tok_pos < lb[:, None, None])
    sc = jnp.where(valid[:, None, None], sc, NEG_INF)
    sc = sc.reshape(B, KV, G, P * ps)
    m = sc.max(-1)
    p = jnp.exp(sc - m[..., None])
    l = p.sum(-1)
    out = jnp.einsum("bkgn,bnkd->bkgd", p.reshape(B, KV, G, P * ps),
                     vg.reshape(B, P * ps, KV, dh).astype(jnp.float32))
    out = out / l[..., None]
    lse = m + jnp.log(l)
    return out.reshape(B, Hq, dh), lse.reshape(B, Hq)


# ---------------------------------------------------------------------------
# 3. Causal flash attention (train / prefill)
# ---------------------------------------------------------------------------


def flash_attention(
    q: jnp.ndarray,   # [B, S, H, dh]
    k: jnp.ndarray,   # [B, S, KV, dh]
    v: jnp.ndarray,   # [B, S, KV, dh]
    *,
    window: Optional[int] = None,
) -> jnp.ndarray:
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    kexp = jnp.repeat(k, G, axis=2)
    vexp = jnp.repeat(v, G, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) / np.sqrt(dh),
                    kexp.astype(jnp.float32))
    pos = jnp.arange(S)
    mask = pos[:, None] >= pos[None, :]
    if window:
        mask &= pos[:, None] - pos[None, :] < window
    sc = jnp.where(mask[None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vexp.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# 4. LServe page-wise min/max pooling (prepare-memory stage)
# ---------------------------------------------------------------------------


def page_minmax(k_cache: jnp.ndarray, page_size: int):
    """[B, S, KV, dh] -> (min, max) [B, S/ps, KV, dh]."""
    B, S, KV, dh = k_cache.shape
    kp = k_cache.reshape(B, S // page_size, page_size, KV, dh).astype(jnp.float32)
    return kp.min(axis=2), kp.max(axis=2)


def lserve_page_scores(q: jnp.ndarray, pmin: jnp.ndarray, pmax: jnp.ndarray):
    """LServe relevancy: per page max(q . min, q . max) summed over channels.

    q [B,Hq,dh] -> scores [B, n_pages] (mean over query heads).
    score = sum_c max(q_c * min_c, q_c * max_c)   per (head, page) -> mean_h
    """
    qf = q.astype(jnp.float32)
    # channel-wise max of the two products, then sum over channels
    prod_min = qf[:, :, None, None, :] * pmin.astype(jnp.float32)[:, None]  # [B,H,P,KV,dh]
    prod_max = qf[:, :, None, None, :] * pmax.astype(jnp.float32)[:, None]
    sc = jnp.maximum(prod_min, prod_max).sum(-1)  # [B, H, P, KV]
    return sc.max(-1).mean(1)  # max over kv heads, mean over q heads -> [B, P]


# ---------------------------------------------------------------------------
# 5. BM25 scoring + top-k (RAG relevancy+retrieval)
# ---------------------------------------------------------------------------


def bm25_scores(tf: jnp.ndarray, doc_len: jnp.ndarray, idf: jnp.ndarray,
                *, k1: float = 1.5, b: float = 0.75, avgdl: float = 100.0):
    """tf [B, D, T] term counts; doc_len [B, D]; idf [B, T] -> scores [B, D]."""
    tff = tf.astype(jnp.float32)
    denom = tff + k1 * (1.0 - b + b * doc_len.astype(jnp.float32)[..., None] / avgdl)
    return jnp.einsum("bt,bdt->bd", idf.astype(jnp.float32),
                      tff * (k1 + 1.0) / denom)


def bm25_topk(tf, doc_len, idf, k: int, **kw):
    scores = bm25_scores(tf, doc_len, idf, **kw)
    return jax.lax.top_k(scores, min(k, scores.shape[-1]))  # match ops path
