"""Blockwise causal flash attention (train / prefill path).

Standard FlashAttention-2 tiling: grid (B, H, nq, nk) with the KV dimension
innermost-sequential; running (m, l, acc) live in VMEM scratch. GQA is folded
into the K/V BlockSpec index map (kv_head = h // G — static arithmetic, no
data-dependent indexing). Optional sliding window (Mixtral).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, bq: int, bk: int, nk: int, window: int, seq: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = i * bq + jax.lax.iota(jnp.int32, bq)
    kpos = j * bk + jax.lax.iota(jnp.int32, bk)
    # block-level causal skip: this KV block starts after the last query row
    needed = (j * bk) <= (i * bq + bq - 1)
    if window:
        # window skip: KV block ends before the window of the first query row
        needed &= (j * bk + bk - 1) > (i * bq - window - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, dh]
        v = v_ref[0, 0].astype(jnp.float32)
        dh = q.shape[-1]
        sc = jnp.dot(q / np.sqrt(dh), k.T, preferred_element_type=jnp.float32)
        mask = qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        mask &= (kpos < seq)[None, :]
        sc = jnp.where(mask, sc, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, sc.max(axis=-1, keepdims=True))
        p = jnp.exp(sc - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "window", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, S, H, dh]
    k: jnp.ndarray,  # [B, S, KV, dh]
    v: jnp.ndarray,  # [B, S, KV, dh]
    *,
    bq: int = 512,
    bk: int = 512,
    window: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    bq, bk = min(bq, S), min(bk, S)
    pad = (-S) % bq
    qt = jnp.moveaxis(q, 1, 2)  # [B, H, S, dh]
    kt = jnp.moveaxis(k, 1, 2)  # [B, KV, S, dh]
    vt = jnp.moveaxis(v, 1, 2)
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nq, nk = Sp // bq, Sp // bk
    kern = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, window=window, seq=S)
    out = pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :S], 2, 1)  # [B, S, H, dh]
