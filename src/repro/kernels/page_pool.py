"""LServe page-wise min/max pooling — the Prepare-Memory stage.

Each logical page of the key cache is summarized by its channel-wise min and
max vectors; the relevancy stage then bounds q.k over the page by
max(q*min, q*max) per channel. One grid step per (batch, page).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(k_ref, min_ref, max_ref):
    blk = k_ref[0, 0].astype(jnp.float32)  # [ps, KV, dh]
    min_ref[0, 0] = blk.min(axis=0)
    max_ref[0, 0] = blk.max(axis=0)


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def page_minmax(k_cache: jnp.ndarray, *, page_size: int = 64,
                interpret: bool = True):
    """[B, S, KV, dh] -> (min, max) [B, S/ps, KV, dh] fp32."""
    B, S, KV, dh = k_cache.shape
    ps = page_size
    assert S % ps == 0
    n_pages = S // ps
    kp = k_cache.reshape(B, n_pages, ps, KV, dh)
    return pl.pallas_call(
        _kernel,
        grid=(B, n_pages),
        in_specs=[pl.BlockSpec((1, 1, ps, KV, dh), lambda b, p: (b, p, 0, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, KV, dh), lambda b, p: (b, p, 0, 0)),
            pl.BlockSpec((1, 1, KV, dh), lambda b, p: (b, p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_pages, KV, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, n_pages, KV, dh), jnp.float32),
        ],
        interpret=interpret,
    )(kp)
