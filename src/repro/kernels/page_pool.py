"""Paged KV-pool primitives + LServe page-wise min/max pooling.

Two groups of device code live here:

* Paged-pool access (``pool_gather`` / ``pool_scatter_token`` /
  ``pool_scatter_span``): the serving engine stores KV in a shared pool of
  fixed-size physical pages ``[n_pages, page_size, KV, dh]`` and addresses it
  through per-slot page tables, so HBM scales with *live* tokens instead of
  ``n_slots * max_len``. On CPU/XLA the gather materializes a contiguous
  per-slot view (advanced-indexing gather — XLA lowers it to a DMA-friendly
  dynamic-gather); on TPU the paged Pallas kernel in
  ``sparse_decode_attention.py`` consumes the page table directly via
  scalar-prefetch block index maps, so the materialized view is never needed
  on the sparse path.

* ``page_minmax``: the LServe Prepare-Memory stage. Each logical page of the
  key cache is summarized by its channel-wise min and max vectors; the
  relevancy stage then bounds q.k over the page by max(q*min, q*max) per
  channel. One grid step per (batch, page).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Paged-pool gather / scatter
# ---------------------------------------------------------------------------


def pool_gather(pages: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """Materialize contiguous per-slot views from the shared page pool.

    pages [P, ps, KV, dh]; page_table [B, NP] int32 (physical page id per
    logical page; unallocated entries point at the reserved zero page 0)
    -> [B, NP * ps, KV, dh].
    """
    P, ps, KV, dh = pages.shape
    B, NP = page_table.shape
    view = pages[page_table]                      # [B, NP, ps, KV, dh]
    return view.reshape(B, NP * ps, KV, dh)


def pool_scatter_token(pages: jnp.ndarray, page_table: jnp.ndarray,
                       positions: jnp.ndarray, values: jnp.ndarray,
                       live: jnp.ndarray) -> jnp.ndarray:
    """Write one new token per slot into the pool.

    pages [P, ps, KV, dh]; page_table [B, NP]; positions [B] (logical token
    position being written); values [B, KV, dh]; live [B] bool. Dead slots
    write ZEROS to the reserved trash page 0 so the pool stays clean (the
    zero page is part of every unallocated page-table entry and must remain
    zero for pooled decode to match per-request decode exactly).
    """
    ps = pages.shape[1]
    B = positions.shape[0]
    NP = page_table.shape[1]
    logical = jnp.clip(positions // ps, 0, NP - 1)  # dead slots can sit at NP
    dest = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    dest = jnp.where(live, dest, 0)
    off = positions % ps
    vals = values * live[:, None, None].astype(values.dtype)
    return pages.at[dest, off].set(vals)


def pool_scatter_span(pages: jnp.ndarray, page_table: jnp.ndarray,
                      start: jnp.ndarray, values: jnp.ndarray,
                      n_valid: jnp.ndarray) -> jnp.ndarray:
    """Write a span of C new tokens per slot (chunked prefill).

    pages [P, ps, KV, dh]; page_table [B, NP]; start [B] (first logical
    position of the span); values [B, C, KV, dh]; n_valid [B] (tokens of the
    span that are real — the rest are padding and are routed, zeroed, to the
    trash page 0).
    """
    ps = pages.shape[1]
    B, C = values.shape[:2]
    tok_pos = start[:, None] + jnp.arange(C)[None, :]          # [B, C]
    valid = jnp.arange(C)[None, :] < n_valid[:, None]          # [B, C]
    logical = jnp.clip(tok_pos // ps, 0, page_table.shape[1] - 1)
    dest = jnp.take_along_axis(page_table, logical, axis=1)    # [B, C]
    dest = jnp.where(valid, dest, 0)
    off = tok_pos % ps
    vals = values * valid[:, :, None, None].astype(values.dtype)
    return pages.at[dest, off].set(vals)


def _kernel(k_ref, min_ref, max_ref):
    blk = k_ref[0, 0].astype(jnp.float32)  # [ps, KV, dh]
    min_ref[0, 0] = blk.min(axis=0)
    max_ref[0, 0] = blk.max(axis=0)


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def page_minmax(k_cache: jnp.ndarray, *, page_size: int = 64,
                interpret: bool = True):
    """[B, S, KV, dh] -> (min, max) [B, S/ps, KV, dh] fp32."""
    B, S, KV, dh = k_cache.shape
    ps = page_size
    assert S % ps == 0
    n_pages = S // ps
    kp = k_cache.reshape(B, n_pages, ps, KV, dh)
    return pl.pallas_call(
        _kernel,
        grid=(B, n_pages),
        in_specs=[pl.BlockSpec((1, 1, ps, KV, dh), lambda b, p: (b, p, 0, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, KV, dh), lambda b, p: (b, p, 0, 0)),
            pl.BlockSpec((1, 1, KV, dh), lambda b, p: (b, p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_pages, KV, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, n_pages, KV, dh), jnp.float32),
        ],
        interpret=interpret,
    )(kp)
