"""Paged sparse decode attention — the Apply-to-Inference stage.

Gathers ONLY the retrieved KV pages (top-k indices from the relevancy kernel)
directly HBM->VMEM via a scalar-prefetch block index map (the TPU analogue of
the paper keeping KV extraction on the engine that owns the KV, §5.2), and
runs a FlashDecoding-style online softmax over them.

Emits (out, lse) so sequence-sharded shards can LSE-merge partial results —
the distributed form exchanges only (out, lse) pairs, never KV.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pages_ref, length_ref, q_ref, k_ref, v_ref, out_ref, lse_ref,
            m_scr, l_scr, acc_scr, *, ps: int, n_sel: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    page_id = pages_ref[b, j]
    length = length_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)            # [G, dh]
    k = k_ref[0, 0, :, 0].astype(jnp.float32)      # [ps, dh]
    v = v_ref[0, 0, :, 0].astype(jnp.float32)      # [ps, dh]
    dh = q.shape[-1]
    sc = jnp.dot(q / np.sqrt(dh), k.T,
                 preferred_element_type=jnp.float32)  # [G, ps]
    tok = page_id * ps + jax.lax.iota(jnp.int32, ps)
    valid = (page_id >= 0) & (tok < length)
    sc = jnp.where(valid[None, :], sc, NEG_INF)

    m_prev = m_scr[...]                            # [G, 1]
    m_new = jnp.maximum(m_prev, sc.max(axis=-1, keepdims=True))
    p = jnp.exp(sc - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_sel - 1)
    def _finish():
        l = l_scr[...]
        out_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(out_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(jnp.maximum(l, 1e-30)))[:, 0]


@functools.partial(
    jax.jit, static_argnames=("page_size", "interpret"),
)
def paged_decode_attention(
    q: jnp.ndarray,         # [B, Hq, dh]
    k_cache: jnp.ndarray,   # [B, S, KV, dh]
    v_cache: jnp.ndarray,   # [B, S, KV, dh]
    page_ids: jnp.ndarray,  # [B, P] int32 page indices, -1 invalid
    length: jnp.ndarray,    # [B] int32 valid token count
    *,
    page_size: int = 64,
    interpret: bool = True,
):
    """-> (out [B, Hq, dh] fp32, lse [B, Hq] fp32)."""
    B, S, KV, dh = k_cache.shape
    Hq = q.shape[1]
    G = Hq // KV
    ps = page_size
    assert S % ps == 0
    n_pages = S // ps
    n_sel = page_ids.shape[1]
    qg = q.reshape(B, KV, G, dh)
    kp = k_cache.reshape(B, n_pages, ps, KV, dh)
    vp = v_cache.reshape(B, n_pages, ps, KV, dh)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))

    kern = functools.partial(_kernel, ps=ps, n_sel=n_sel)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_sel),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h, j, pages, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, 1, dh),
                         lambda b, h, j, pages, lens: (
                             b, jnp.maximum(pages[b, j], 0), 0, h, 0)),
            pl.BlockSpec((1, 1, ps, 1, dh),
                         lambda b, h, j, pages, lens: (
                             b, jnp.maximum(pages[b, j], 0), 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h, j, pages, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, j, pages, lens: (b, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
    )
    out, lse = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
        ],
        interpret=interpret,
    )(page_ids, length, qg, kp, vp)
    return out.reshape(B, Hq, dh), lse.reshape(B, Hq)


def lse_merge(outs: jnp.ndarray, lses: jnp.ndarray):
    """Merge N partial attention results: outs [N, B, H, dh], lses [N, B, H].

    Standard FlashDecoding combine: softmax over shard LSEs reweights shard
    outputs. This is the only cross-shard math in distributed sparse decode.
    """
    m = lses.max(axis=0)                              # [B, H]
    w = jnp.exp(lses - m[None])                       # [N, B, H]
    den = w.sum(axis=0)
    out = (outs * w[..., None]).sum(axis=0) / jnp.maximum(den[..., None], 1e-30)
    return out, m + jnp.log(jnp.maximum(den, 1e-30))
