"""Pallas TPU kernels for the memory-processing hot spots the paper offloads:
fused relevancy+top-k (FPGA General Setup engine), paged sparse decode
attention, flash attention, page min/max pooling (LServe prepare), and fused
BM25+top-k (RAG). Public API in ``ops``; oracles in ``ref``.
"""
from repro.kernels import ops, ref  # noqa: F401
