"""Fused Compute-Relevancy + Retrieval kernel (the paper's FPGA "General
Setup" engine, Fig. 7, adapted to TPU).

One pallas_call fuses, per key block:
  1. multi-head inner-product scoring against the compressed key/index
     vectors (MXU matmul, keys streamed HBM->VMEM exactly once),
  2. head-weighted ReLU reduction (DSA lightning indexer),
  3. an in-VMEM bitonic top-c selection — scores never round-trip to HBM.

Only (c values, c indices) per block leave the kernel (the paper's
"transfer only the top-k indices over PCIe" principle — here it bounds both
HBM writeback and the cross-device exchange of the distributed top-k).

TPU adaptation note (DESIGN.md §2): the FPGA maintains ONE running top-k list
sequentially; a TPU prefers the two-stage data-parallel form — exact per-block
top-c (bitonic network on the VPU) + a cheap global merge of nb*c candidates.
Exactness: global top-k is a subset of the union of per-block
top-min(k, block) candidates, so c >= min(k, block) => exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.bitonic import bitonic_topk


def _kernel(q_ref, k_ref, w_ref, vals_ref, idx_ref, *, block: int, c: int,
            valid_len: int):
    j = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)          # [Hq, dk]
    keys = k_ref[0].astype(jnp.float32)       # [block, dk]
    w = w_ref[0].astype(jnp.float32)          # [Hq]
    # 1) multi-head inner product on the MXU
    dots = jnp.dot(keys, q.T, preferred_element_type=jnp.float32)  # [block, Hq]
    # 2) weighted ReLU reduction -> one score per key
    scores = jax.nn.relu(dots) @ w            # [block]
    idx = j * block + jax.lax.iota(jnp.int32, block)
    scores = jnp.where(idx < valid_len, scores, -jnp.inf)
    # 3) in-VMEM bitonic top-c (no HBM writeback of raw scores)
    top_v, top_pos = bitonic_topk(scores[None, :],
                                  jax.lax.iota(jnp.int32, block)[None, :], c)
    vals_ref[0, 0] = top_v[0]
    idx_ref[0, 0] = j * block + top_pos[0]


@functools.partial(
    jax.jit,
    static_argnames=("block", "c", "valid_len", "interpret"),
)
def relevancy_topk_candidates(
    q: jnp.ndarray,        # [B, Hq, dk]
    keys: jnp.ndarray,     # [B, S, dk]  compressed key / index vectors
    weights: jnp.ndarray,  # [B, Hq]     per-head query weights
    *,
    block: int = 2048,
    c: int = 0,            # candidates per block; 0 -> min(block, S)
    valid_len: int = 0,    # 0 -> S (static; dynamic masking happens on merge)
    interpret: bool = True,
):
    """Per-block candidates: (vals [B, nb, c], idx [B, nb, c])."""
    B, S, dk = keys.shape
    Hq = q.shape[1]
    block = min(block, S)
    assert S % block == 0, (S, block)
    nb = S // block
    c = c or block
    c = min(c, block)
    valid_len = valid_len or S
    kern = functools.partial(_kernel, block=block, c=c, valid_len=valid_len)
    return pl.pallas_call(
        kern,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, Hq, dk), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block, dk), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, Hq), lambda b, j: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, c), lambda b, j: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nb, c), jnp.float32),
            jax.ShapeDtypeStruct((B, nb, c), jnp.int32),
        ],
        interpret=interpret,
    )(q, keys, weights)


def merge_candidates(vals: jnp.ndarray, idx: jnp.ndarray, k: int):
    """Global merge: [B, nb, c] -> exact top-k over all candidates."""
    B = vals.shape[0]
    flat_v = vals.reshape(B, -1)
    flat_i = idx.reshape(B, -1)
    top_v, pos = jax.lax.top_k(flat_v, k)
    return top_v, jnp.take_along_axis(flat_i, pos, axis=1)
