"""Public jit'd kernel API. On CPU the Pallas kernels run in interpret mode
(exact same kernel body, validated against ref.py); on TPU they compile via
Mosaic. ``use_pallas(False)`` routes everything through the ref oracles
(useful under 512-device dry-run lowering where interpret-mode overhead in
the traced graph is unwanted).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import relevancy_topk as _rt
from repro.kernels import sparse_decode_attention as _sda
from repro.kernels import flash_attention as _fa
from repro.kernels import page_pool as _pp
from repro.kernels import bm25_topk as _bm

_STATE = {"pallas": True}


def use_pallas(flag: bool) -> None:
    _STATE["pallas"] = flag


def pallas_enabled() -> bool:
    return _STATE["pallas"]


def _interp() -> bool:
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------


def _pow2_block(n: int, want: int) -> int:
    """Largest power-of-two block <= want that is also >= 2."""
    b = 1
    while b * 2 <= min(n, want):
        b *= 2
    return max(b, 2)


def relevancy_topk(q, keys, weights, k: int, *, block: int = 2048,
                   c: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused score + top-k. Exact when c=0 (c -> min(block, S)).

    Pads the key axis to a power-of-two block multiple (the kernel masks the
    pad with -inf via valid_len), so any context length is accepted.
    """
    if not _STATE["pallas"]:
        return ref.relevancy_topk(q, keys, weights, k)
    B, S, dk = keys.shape
    blk = _pow2_block(max(S, 2), block)
    pad = (-S) % blk
    if pad:
        keys = jnp.pad(keys, ((0, 0), (0, pad), (0, 0)))
    vals, idx = _rt.relevancy_topk_candidates(
        q, keys, weights, block=blk, c=c, valid_len=S, interpret=_interp())
    return _rt.merge_candidates(vals, idx, min(k, S))


def paged_decode_attention(q, k_cache, v_cache, page_ids, length, *,
                           page_size: int = 64):
    if not _STATE["pallas"]:
        return ref.paged_decode_attention(q, k_cache, v_cache, page_ids,
                                          page_size, length)
    return _sda.paged_decode_attention(q, k_cache, v_cache, page_ids, length,
                                       page_size=page_size,
                                       interpret=_interp())


lse_merge = _sda.lse_merge


def flash_attention(q, k, v, *, window: int = 0, bq: int = 512, bk: int = 512):
    if not _STATE["pallas"]:
        return ref.flash_attention(q, k, v, window=window or None)
    return _fa.flash_attention(q, k, v, bq=bq, bk=bk, window=window,
                               interpret=_interp())


def page_minmax(k_cache, *, page_size: int = 64):
    if not _STATE["pallas"]:
        return ref.page_minmax(k_cache, page_size)
    return _pp.page_minmax(k_cache, page_size=page_size, interpret=_interp())


def bm25_topk(tf, doc_len, idf, k: int, *, block: int = 4096, c: int = 0,
              k1: float = 1.5, b: float = 0.75, avgdl: float = 100.0,
              valid=None):
    """Fused BM25 score + top-k. ``valid`` restricts scoring to the first
    ``valid`` documents (traced ok — the serving corpus store passes its
    live doc count so ingest never re-jits); None scores all D docs."""
    B, D, T = tf.shape
    if not _STATE["pallas"]:
        if valid is None:
            return ref.bm25_topk(tf, doc_len, idf, k, k1=k1, b=b, avgdl=avgdl)
        scores = ref.bm25_scores(tf, doc_len, idf, k1=k1, b=b, avgdl=avgdl)
        scores = jnp.where(jnp.arange(D)[None] < valid, scores, -jnp.inf)
        return jax.lax.top_k(scores, min(k, D))
    blk = _pow2_block(max(D, 2), block)
    pad = (-D) % blk
    if pad:
        tf = jnp.pad(tf, ((0, 0), (0, pad), (0, 0)))
        doc_len = jnp.pad(doc_len, ((0, 0), (0, pad)), constant_values=1.0)
    c = c or min(k, blk)
    vals, idx = _bm.bm25_topk_candidates(
        tf, doc_len, idf, block=blk, c=c, k1=k1, b=b, avgdl=avgdl,
        valid=D if valid is None else valid, interpret=_interp())
    return _rt.merge_candidates(vals, idx, min(k, D))
