"""Fused BM25 scoring + top-k over gathered term columns (RAG relevancy +
retrieval, paper Fig. 10 right / Table 1 "BM25 + Top-k").

TPU adaptation (DESIGN.md §2): BM25's irregular per-term histogram lookups are
hoisted OUT of the kernel — the data pipeline gathers the query's term-
frequency columns once into a dense [D, T] panel — while the streaming
score + top-k stays fused in VMEM, mirroring the FPGA dataflow engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic import bitonic_topk


def _kernel(tf_ref, dl_ref, idf_ref, vals_ref, idx_ref,
            *, k1: float, b: float, avgdl: float, bd: int, c: int, n_docs: int):
    j = pl.program_id(1)
    tf = tf_ref[0].astype(jnp.float32)        # [bd, T]
    dl = dl_ref[0].astype(jnp.float32)        # [bd]
    idf = idf_ref[0].astype(jnp.float32)      # [T]
    denom = tf + k1 * (1.0 - b + b * dl[:, None] / avgdl)
    scores = (tf * (k1 + 1.0) / denom) @ idf  # [bd]
    idx = j * bd + jax.lax.iota(jnp.int32, bd)
    scores = jnp.where(idx < n_docs, scores, -jnp.inf)
    top_v, top_pos = bitonic_topk(scores[None, :],
                                  jax.lax.iota(jnp.int32, bd)[None, :], c)
    vals_ref[0, 0] = top_v[0]
    idx_ref[0, 0] = j * bd + top_pos[0]


@functools.partial(
    jax.jit,
    static_argnames=("block", "c", "k1", "b", "avgdl", "valid", "interpret"),
)
def bm25_topk_candidates(
    tf: jnp.ndarray,       # [B, D, T] term frequencies (query's terms only)
    doc_len: jnp.ndarray,  # [B, D]
    idf: jnp.ndarray,      # [B, T]
    *,
    block: int = 4096,
    c: int = 64,
    k1: float = 1.5,
    b: float = 0.75,
    avgdl: float = 100.0,
    valid: int = 0,        # 0 -> D; real doc count when padded
    interpret: bool = True,
):
    """Per-block BM25 top-c candidates: (vals [B,nb,c], idx [B,nb,c])."""
    B, D, T = tf.shape
    block = min(block, D)
    assert D % block == 0
    nb = D // block
    c = min(c, block)
    kern = functools.partial(_kernel, k1=k1, b=b, avgdl=avgdl, bd=block, c=c,
                             n_docs=valid or D)
    return pl.pallas_call(
        kern,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, block, T), lambda bi, j: (bi, j, 0)),
            pl.BlockSpec((1, block), lambda bi, j: (bi, j)),
            pl.BlockSpec((1, T), lambda bi, j: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c), lambda bi, j: (bi, j, 0)),
            pl.BlockSpec((1, 1, c), lambda bi, j: (bi, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nb, c), jnp.float32),
            jax.ShapeDtypeStruct((B, nb, c), jnp.int32),
        ],
        interpret=interpret,
    )(tf, doc_len, idf)
