"""Fused BM25 scoring + top-k over gathered term columns (RAG relevancy +
retrieval, paper Fig. 10 right / Table 1 "BM25 + Top-k").

TPU adaptation (DESIGN.md §2): BM25's irregular per-term histogram lookups are
hoisted OUT of the kernel — the data pipeline gathers the query's term-
frequency columns once into a dense [D, T] panel — while the streaming
score + top-k stays fused in VMEM, mirroring the FPGA dataflow engine.

The live document count is a SCALAR-PREFETCH operand (same idiom as the
paged sparse-decode kernel), not a static trace constant: the serving-side
corpus store appends documents incrementally and must not re-jit the
retrieval path every time the corpus grows.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bitonic import bitonic_topk


def _kernel(nd_ref, tf_ref, dl_ref, idf_ref, vals_ref, idx_ref,
            *, k1: float, b: float, avgdl: float, bd: int, c: int):
    j = pl.program_id(1)
    tf = tf_ref[0].astype(jnp.float32)        # [bd, T]
    dl = dl_ref[0].astype(jnp.float32)        # [bd]
    idf = idf_ref[0].astype(jnp.float32)      # [T]
    denom = tf + k1 * (1.0 - b + b * dl[:, None] / avgdl)
    scores = (tf * (k1 + 1.0) / denom) @ idf  # [bd]
    idx = j * bd + jax.lax.iota(jnp.int32, bd)
    scores = jnp.where(idx < nd_ref[0], scores, -jnp.inf)
    top_v, top_pos = bitonic_topk(scores[None, :],
                                  jax.lax.iota(jnp.int32, bd)[None, :], c)
    vals_ref[0, 0] = top_v[0]
    idx_ref[0, 0] = j * bd + top_pos[0]


@functools.partial(
    jax.jit,
    static_argnames=("block", "c", "k1", "b", "avgdl", "interpret"),
)
def bm25_topk_candidates(
    tf: jnp.ndarray,       # [B, D, T] term frequencies (query's terms only)
    doc_len: jnp.ndarray,  # [B, D]
    idf: jnp.ndarray,      # [B, T]
    *,
    block: int = 4096,
    c: int = 64,
    k1: float = 1.5,
    b: float = 0.75,
    avgdl: float = 100.0,
    valid=0,               # live doc count (traced ok); 0 -> D
    interpret: Optional[bool] = None,  # None -> backend-aware (CPU only)
):
    """Per-block BM25 top-c candidates: (vals [B,nb,c], idx [B,nb,c])."""
    B, D, T = tf.shape
    block = min(block, D)
    assert D % block == 0
    nb = D // block
    c = min(c, block)
    if interpret is None:  # match ops._interp(): compile via Mosaic off-CPU
        interpret = jax.default_backend() == "cpu"
    nd = jnp.asarray(valid, jnp.int32)
    nd = jnp.where(nd > 0, nd, D).reshape(1)
    kern = functools.partial(_kernel, k1=k1, b=b, avgdl=avgdl, bd=block, c=c)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, block, T), lambda bi, j, nd: (bi, j, 0)),
            pl.BlockSpec((1, block), lambda bi, j, nd: (bi, j)),
            pl.BlockSpec((1, T), lambda bi, j, nd: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c), lambda bi, j, nd: (bi, j, 0)),
            pl.BlockSpec((1, 1, c), lambda bi, j, nd: (bi, j, 0)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, nb, c), jnp.float32),
            jax.ShapeDtypeStruct((B, nb, c), jnp.int32),
        ],
        interpret=interpret,
    )(nd, tf, doc_len, idf)
