"""Serving-integrated retrieval subsystem (paper Table 1 rows 4-6 and 8).

Dynamic RAG and MaC memory banks as a first-class engine service: the
document memory (corpus index / per-slot banks) lives on the retrieval
device, FLARE/DRAGIN triggers fire per slot over the pooled decode logits,
and retrieved payloads are spliced into the paged KV pool through the
chunked-prefill path — overlapped against decode of the other slots under
``RetrievalConfig(mode="overlap")``, bit-matching the inline synchronous
stop-retrieve-resume schedule.
"""
from repro.retrieval.bank import MacBankService
from repro.retrieval.executor import RetrievalConfig, RetrievalExecutor
from repro.retrieval.select import make_retrieval_select, rag_hybrid_scores
from repro.retrieval.service import RetrievalService

__all__ = [
    "MacBankService", "RetrievalConfig", "RetrievalExecutor",
    "RetrievalService", "make_retrieval_select", "rag_hybrid_scores",
]
