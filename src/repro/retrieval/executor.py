"""Serving-side retrieval executor: dynamic triggers, async queries, splice
scheduling (the engine-facing face of the retrieval subsystem).

Per decode step the engine hands this executor the pooled decode logits;
FLARE / DRAGIN triggers fire PER SLOT, and a fired slot's query (a window
of its recent context tokens) is dispatched to the retrieval device:

  inline   — the service lives on the MAIN device; query resolved
             synchronously at the trigger step (the stop-retrieve-resume
             oracle every other mode must bit-match);
  sync     — service on the OFFLOAD device, still resolved synchronously
             (the honest serialized baseline);
  overlap  — async dispatch: the offload device scores the corpus / bank
             WHILE the main device keeps decoding slots B..Z; the fired
             slot pauses (it leaves the live mask) and its result is
             consumed one step later, double-buffered like the PR-2
             lookahead executor.

The retrieved payload (doc token spans for rag, memory embeddings for mac)
is spliced into the slot's paged KV context by the ENGINE through the
chunked-``extend_paged`` path under the scheduler's prefill token budget;
this module only decides when to fire, runs the queries, and keeps the
per-slot bookkeeping deterministic so every mode emits identical tokens.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.methods import rag as rag_m
from repro.core.methods.mac import MacConfig
from repro.hetero import policy as hpolicy
from repro.hetero.transfer import TransferLedger
from repro.retrieval.bank import MacBankService
from repro.retrieval.service import RetrievalService

MODES = ("inline", "sync", "overlap")


def traced_trigger(kind: str, tau: float, logits, lengths):
    """FLARE/DRAGIN trigger predicate as a PURE traced function — the
    per-step evaluation the fused decode loop runs on device. ``lengths``
    is the pre-step masked length vector (the same array the host
    ``trigger_slots`` receives), so the DRAGIN context weight matches the
    stepped path bit for bit."""
    if kind == "flare":
        return rag_m.flare_trigger(logits, tau=tau)
    if kind == "dragin":
        ent_w = jnp.log1p(jnp.asarray(lengths, jnp.float32))
        return rag_m.dragin_trigger(logits, ent_w, tau=tau)
    raise KeyError(f"unknown trigger {kind!r}")


@dataclasses.dataclass
class RetrievalConfig:
    """``ServeConfig(retrieval=...)`` — the document-memory service knobs."""

    kind: str = "rag"            # rag | mac
    mode: str = "inline"         # inline | sync | overlap
    corpus: Any = None           # rag.Corpus (required for kind=rag)
    k: int = 4                   # docs per retrieval (rag)
    capacity: int = 0            # corpus arena size (0 = pow2 fit)
    ingest_block: int = 64       # docs per jitted append
    mac: Optional[MacConfig] = None   # bank shape (kind=mac)
    trigger: str = "flare"       # flare | dragin
    tau: float = 0.4             # trigger threshold
    query_window: int = 8        # context tokens forming the query
    min_interval: int = 8        # context growth required between triggers
    max_retrievals: int = 2      # per request
    validate: bool = False       # replay every consumed query synchronously
    # a pre-built RetrievalService SHARED across executors (the fleet
    # router's one-corpus-many-replicas topology: the service is
    # capacity-padded and incremental-ingest, so documents ingested
    # through any replica are visible to every replica's triggers).
    # kind='rag' only; None = the executor builds its own service.
    service: Optional[RetrievalService] = None


class RetrievalExecutor:
    def __init__(self, cfg: ArchConfig, sc, rcfg: RetrievalConfig, params,
                 *, key=None, devices=None):
        assert rcfg.mode in MODES, rcfg.mode
        assert rcfg.kind in ("rag", "mac"), rcfg.kind
        self.cfg, self.sc, self.rcfg = cfg, sc, rcfg
        self.mode = rcfg.mode
        self.main_dev, self.off_dev = devices or hpolicy.pick_devices()
        dev = self.main_dev if rcfg.mode == "inline" else self.off_dev
        self.ledger = TransferLedger()
        self.service: Optional[RetrievalService] = None
        self.bank: Optional[MacBankService] = None
        if rcfg.kind == "rag":
            if rcfg.service is not None:
                # fleet-shared corpus: adopt the pre-built service (and its
                # ledger, so cross-replica transfer stats pool in one place)
                self.service = rcfg.service
                if self.service.ledger is not None:
                    self.ledger = self.service.ledger
                if self.service.device is not None:
                    self.off_dev = self.service.device
            else:
                assert rcfg.corpus is not None, "kind='rag' needs a corpus"
                self.service = RetrievalService(
                    rcfg.corpus, k=rcfg.k, device=dev,
                    capacity=rcfg.capacity, ingest_block=rcfg.ingest_block,
                    ledger=self.ledger)
        else:
            mc = rcfg.mac or MacConfig()
            # summaries push at page boundaries: segment = page multiple
            seg = max(mc.segment_len, sc.kv_page_size)
            seg = ((seg + sc.kv_page_size - 1)
                   // sc.kv_page_size) * sc.kv_page_size
            if seg != mc.segment_len:
                mc = dataclasses.replace(mc, segment_len=seg)
            self.mc = mc
            self.bank = MacBankService(cfg, mc, sc.n_slots, params["embed"],
                                       key=key, device=dev,
                                       ledger=self.ledger)
        n = sc.n_slots
        self._enabled = np.zeros((n,), bool)
        self._hist: List[List[int]] = [[] for _ in range(n)]
        self._pushed = np.zeros((n,), np.int64)    # mac: tokens summarized
        self._n_ret = np.zeros((n,), np.int32)
        self._last_len = np.zeros((n,), np.int64)  # context len @ last fire
        self._waiting = np.zeros((n,), bool)
        self._inflight: Dict[int, Dict] = {}       # slot -> handle + age
        self.events: List[Dict] = []
        self.suppressed = 0

    # ------------------------------------------------------------------
    # slot lifecycle (engine hooks)
    # ------------------------------------------------------------------

    def on_admit(self, slot: int, prompt: np.ndarray,
                 enabled: Optional[bool]) -> None:
        assert slot not in self._inflight
        self._enabled[slot] = True if enabled is None else bool(enabled)
        self._hist[slot] = [int(t) for t in np.asarray(prompt)]
        self._pushed[slot] = 0
        self._n_ret[slot] = 0
        self._last_len[slot] = len(self._hist[slot])
        self._waiting[slot] = False
        if self.bank is not None:
            self.bank.reset([slot])
            if self._enabled[slot]:
                self._push_segments(slot)

    def on_release(self, slot: int) -> None:
        assert slot not in self._inflight, "released slot mid-retrieval"
        self._enabled[slot] = False
        self._hist[slot] = []
        self._waiting[slot] = False
        if self.bank is not None:
            self.bank.reset([slot])

    def note_token(self, slot: int, tok: int) -> None:
        """One decode token fed to ``slot`` (entered its KV context)."""
        self._hist[slot].append(int(tok))
        if self.bank is not None and self._enabled[slot]:
            self._push_segments(slot)

    def note_splice(self, slot: int, payload) -> None:
        """Retrieved payload queued into the slot's context: doc tokens for
        rag, ``n`` placeholder rows for mac embeddings (the context history
        tracks positions; embedding rows have no token ids)."""
        if isinstance(payload, (int, np.integer)):
            self._hist[slot].extend([0] * int(payload))
        else:
            self._hist[slot].extend(int(t) for t in np.asarray(payload))
        self._last_len[slot] = len(self._hist[slot])
        if self.bank is not None and self._enabled[slot]:
            self._push_segments(slot)

    def _push_segments(self, slot: int) -> None:
        seg = self.mc.segment_len
        hist = self._hist[slot]
        while len(hist) - self._pushed[slot] >= seg:
            lo = int(self._pushed[slot])
            self.bank.push(slot, np.asarray(hist[lo: lo + seg], np.int32))
            self._pushed[slot] += seg

    # ------------------------------------------------------------------
    # triggers
    # ------------------------------------------------------------------

    def trigger_slots(self, logits, live_np: np.ndarray,
                      lengths_np: np.ndarray, slots) -> List[int]:
        """Slots whose dynamic-retrieval trigger fires on this step's
        logits, after the deterministic host-side gates (enabled, cooldown,
        retrieval budget, bank occupancy, not already in flight)."""
        r = self.rcfg
        if r.trigger == "flare":
            fire = np.asarray(rag_m.flare_trigger(logits, tau=r.tau))
        elif r.trigger == "dragin":
            # attention-statistics proxy: log-context weight (deterministic,
            # available without re-running attention)
            ent_w = jnp.log1p(jnp.asarray(lengths_np, jnp.float32))
            fire = np.asarray(rag_m.dragin_trigger(logits, ent_w, tau=r.tau))
        else:
            raise KeyError(f"unknown trigger {r.trigger!r}")
        out = []
        for i in np.flatnonzero(fire & live_np & self._enabled):
            s = slots[i]
            if s.done or self._waiting[i] or i in self._inflight:
                continue
            if self._n_ret[i] >= r.max_retrievals:
                continue
            if len(self._hist[i]) - self._last_len[i] < r.min_interval:
                continue
            if self.bank is not None and self.bank.counts[i] == 0:
                continue
            out.append(int(i))
        return out

    def fused_gates(self):
        """Host gates of ``trigger_slots`` compiled into per-slot scalars a
        fused window can evaluate on device without a host turn.

        ``armed [B] bool`` folds the static gates (enabled, not waiting,
        retrieval budget); the countdown gates become ``arm_after [B]
        int32`` — the in-window EMITTED-TOKEN count at which they open,
        valid because a slot's history grows by exactly one token per
        emitted token while no splice lands (the engine only enters fused
        windows with the retrieval subsystem quiescent):

          cooldown   len(hist) - last_len >= min_interval
                     -> emitted >= min_interval - (len(hist0) - last_len)
          mac bank   counts[i] > 0 after the next segment push
                     -> emitted >= segment_len - (len(hist0) - pushed)
        """
        r = self.rcfg
        n = self.sc.n_slots
        armed = (self._enabled & ~self._waiting
                 & (self._n_ret < r.max_retrievals))
        for i in self._inflight:
            armed[i] = False
        h0 = np.asarray([len(h) for h in self._hist], np.int64)
        arm_after = (r.min_interval - (h0 - self._last_len)).astype(np.int32)
        if self.bank is not None:
            bank_need = np.where(
                self.bank.counts > 0, np.int32(-(1 << 30)),
                (self.mc.segment_len - (h0 - self._pushed)).astype(np.int32))
            arm_after = np.maximum(arm_after, bank_need)
        return armed, arm_after

    def splice_bound(self) -> int:
        """Upper bound on spliced tokens per retrieval — page reservation
        happens at the trigger step so the pool accounting is identical
        under every scheduling mode."""
        if self.service is not None:
            return self.rcfg.k * self.service._tokens.shape[1]
        return self.mc.retrieve_k

    def note_suppressed(self, slot: int) -> None:
        """Trigger fired but the pool/window cannot take the splice; charge
        the cooldown so the slot does not re-fire every step."""
        self.suppressed += 1
        self._last_len[slot] = len(self._hist[slot])

    # ------------------------------------------------------------------
    # query launch / collection
    # ------------------------------------------------------------------

    def _query_window(self, slot: int) -> np.ndarray:
        W = self.rcfg.query_window
        h = self._hist[slot][-W:]
        if len(h) < W:
            h = [0] * (W - len(h)) + h
        return np.asarray(h, np.int32)

    def launch(self, slot: int) -> None:
        """Dispatch the fired slot's query. ONE dataflow for every mode —
        the slot pauses and its splice queues on the NEXT step regardless
        (so co-resident services like the hetero lookahead see identical
        host schedules); modes differ only in barriers: sync/inline block
        here, overlap lets the retrieval device run under the next decode
        step."""
        toks = self._query_window(slot)
        t0 = time.perf_counter()
        if self.service is not None:
            handle = self.service.query(toks[None] % self.service.vocab)
        else:
            handle = self.bank.query(slot, toks)
        if self.mode != "overlap":
            jax.block_until_ready(handle["ids"])
        self._inflight[slot] = {"handle": handle, "age": 0, "t0": t0,
                                "hist_len": len(self._hist[slot])}
        self._waiting[slot] = True
        self._n_ret[slot] += 1
        self._last_len[slot] = len(self._hist[slot])

    def tick(self) -> None:
        for rec in self._inflight.values():
            rec["age"] += 1

    def collect_ready(self, min_age: int = 1) -> List:
        """Consume finished queries: -> [(slot, tokens|None, embeds|None,
        ids)]. Overlap collects with ``min_age>=1`` (the offload device had
        a full decode step of concurrent wall time); sync/inline collect
        immediately with ``min_age=0``."""
        out = []
        for slot in sorted(self._inflight):
            rec = self._inflight[slot]
            if rec["age"] < min_age:
                continue
            h = rec["handle"]
            if self.service is not None:
                ids, spans = self.service.collect(h, device=self.main_dev)
                toks, embeds, ids = spans[0], None, ids[0]
                if self.rcfg.validate:
                    assert self.service.replay(h), \
                        "overlapped rag query diverged from its replay"
            else:
                ids, embeds = self.bank.collect(h, device=self.main_dev)
                toks = None
                if self.rcfg.validate:
                    assert self.bank.replay(h), \
                        "overlapped mac query diverged from its replay"
            del self._inflight[slot]
            self._waiting[slot] = False
            self.events.append({
                "slot": slot, "ids": np.asarray(ids).tolist(),
                "hist_len": rec["hist_len"],
                "spliced": int(len(toks) if toks is not None
                               else len(embeds)),
                "latency_s": time.perf_counter() - rec["t0"],
            })
            out.append((slot, toks, embeds, ids))
        return out

    # ------------------------------------------------------------------

    def waiting_mask(self) -> np.ndarray:
        return self._waiting.copy()

    def busy(self) -> bool:
        return bool(self._inflight) or bool(self._waiting.any())

    def report(self) -> Dict:
        lat = [e["latency_s"] for e in self.events]
        return {
            "kind": self.rcfg.kind,
            "mode": self.mode,
            "trigger": self.rcfg.trigger,
            "retrievals": len(self.events),
            "suppressed": self.suppressed,
            "spliced_tokens": int(sum(e["spliced"] for e in self.events)),
            "trigger_to_splice_s": {
                "mean": float(np.mean(lat)) if lat else 0.0,
                "max": float(np.max(lat)) if lat else 0.0,
            },
            "transfer": self.ledger.as_dict(),
            "devices": {"main": str(self.main_dev),
                        "retrieval": str(self.off_dev
                                         if self.mode != "inline"
                                         else self.main_dev),
                        "distinct": self.mode != "inline"
                        and self.main_dev != self.off_dev},
        }
