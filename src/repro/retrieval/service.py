"""RAG document-memory service — the serving-side retrieval engine.

``RetrievalService`` hosts the corpus index (TF stats, IDF, doc lengths,
embeddings, doc token payloads) as capacity-padded arrays COMMITTED to one
JAX device — the offload device under ``mode=sync|overlap``, the main
device inline — and answers term-id queries with the fused BM25 kernel
*there*. Only ``[B, k]`` doc ids cross back (index-only exchange, PR-2
style); the doc token spans the generator splices are assembled from the
host-side token mirror and accounted separately as span traffic.

Incremental ingest: documents are appended through one jitted
``dynamic_update_slice`` per array at a fixed ``ingest_block`` row count, so
growing the corpus never re-jits while the capacity holds; when it does not,
the capacity doubles (amortized — the next select/ingest recompiles once for
the new static shape).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.methods.rag import Corpus
from repro.hetero.transfer import TransferLedger
from repro.retrieval.select import make_retrieval_select, rag_hybrid_scores


class RetrievalService:
    def __init__(self, corpus: Corpus, *, k: int, device=None,
                 capacity: int = 0, ingest_block: int = 64,
                 ledger: Optional[TransferLedger] = None):
        assert corpus.n_docs >= k, "corpus smaller than the retrieval k"
        self.k = k
        self.device = device or jax.devices()[0]
        self.ingest_block = ingest_block
        self.ledger = ledger or TransferLedger()
        self.sel = make_retrieval_select("rag", corpus=corpus, k=k,
                                         capacity=capacity,
                                         ingest_block=ingest_block)
        self.state = jax.device_put(self.sel.summary_init(), self.device)
        self._select_jit = jax.jit(self.sel.select)
        self._ingest_jit = jax.jit(self.sel.ingest)
        self._hybrid_jit = jax.jit(rag_hybrid_scores,
                                   static_argnames=("alpha",))
        self.n_docs = corpus.n_docs
        self.capacity = self.sel.n_pages
        # host mirror of the token payloads for span assembly
        dmax = corpus.doc_tokens.shape[1]
        self._tokens = np.zeros((self.capacity, dmax), np.int32)
        self._tokens[: self.n_docs] = np.asarray(corpus.doc_tokens)
        self._tok_len = np.zeros((self.capacity,), np.int32)
        self._tok_len[: self.n_docs] = np.asarray(corpus.doc_len, np.int32)
        self.vocab = corpus.tf.shape[1]

    # -- incremental ingest --------------------------------------------

    # the doc-axis arrays of the store state (df/idf/n_docs are NOT padded
    # on growth — df/idf run over the retrieval vocab, which can collide
    # with the capacity by shape alone)
    DOC_AXIS = ("tf", "doc_len", "doc_tokens", "doc_embeds")

    def _grow(self, need: int) -> None:
        """Double the arena (select/ingest read capacity from the state
        shapes, so the next call re-traces once for the new static shape)."""
        cap = self.capacity
        new_cap = cap
        while new_cap < need:
            new_cap *= 2
        pad = new_cap - cap
        self.state = jax.device_put(
            {k: (jnp.pad(v, ((0, pad),) + ((0, 0),) * (v.ndim - 1))
                 if k in self.DOC_AXIS else v)
             for k, v in self.state.items()}, self.device)
        self._tokens = np.pad(self._tokens, ((0, pad), (0, 0)))
        self._tok_len = np.pad(self._tok_len, (0, pad))
        self.capacity = new_cap

    def ingest(self, corpus: Corpus) -> None:
        """Append ``corpus``'s documents to the store (incremental prepare
        stage: df/idf refresh on device, token mirror on host)."""
        tf = np.asarray(corpus.tf)
        dl = np.asarray(corpus.doc_len, np.float32)
        toks = np.asarray(corpus.doc_tokens)
        emb = None if corpus.doc_embeds is None \
            else np.asarray(corpus.doc_embeds)
        assert tf.shape[1] == self.vocab, "retrieval vocab mismatch"
        assert toks.shape[1] == self._tokens.shape[1], "doc_max mismatch"
        de = self.state.get("doc_embeds")
        if de is not None:
            assert emb is not None and emb.shape[1] == de.shape[1], \
                "store keeps doc embeddings: ingested corpus must carry " \
                "matching-dimension doc_embeds"
        mb = self.ingest_block
        for lo in range(0, tf.shape[0], mb):
            hi = min(lo + mb, tf.shape[0])
            m = hi - lo
            if self.n_docs + m > self.capacity:   # live docs overflow only
                self._grow(self.n_docs + m)
            pad = ((0, mb - m), (0, 0))
            tf_b = jnp.asarray(np.pad(tf[lo:hi], pad))
            dl_b = jnp.asarray(np.pad(dl[lo:hi], (0, mb - m)))
            tk_b = jnp.asarray(np.pad(toks[lo:hi], pad))
            de = self.state.get("doc_embeds")
            eb_b = jnp.zeros((mb, 1), jnp.float32) if de is None else \
                jnp.asarray(np.pad(emb[lo:hi], pad))
            args = self.ledger.ship_down(
                (tf_b, dl_b, tk_b, eb_b), self.device, bulk=True)
            self.state = self._ingest_jit(self.state, *args,
                                          jnp.asarray(m, jnp.int32))
            self._tokens[self.n_docs: self.n_docs + m] = toks[lo:hi]
            self._tok_len[self.n_docs: self.n_docs + m] = dl[lo:hi].astype(
                np.int32)
            self.n_docs += m

    # -- queries --------------------------------------------------------

    def query(self, terms: np.ndarray) -> Dict:
        """Launch a BM25 top-k query for ``terms [B, T]`` on the hosting
        device (async — collect with ``collect``). Returns a handle that
        pins the state the selection was computed from (for validation)."""
        t = self.ledger.ship_down(jnp.asarray(terms, jnp.int32), self.device)
        state = self.state
        scores, ids = self._select_jit(None, state, t)
        return {"scores": scores, "ids": ids, "inputs": (state, t)}

    def collect(self, handle: Dict, device=None
                ) -> Tuple[np.ndarray, List[np.ndarray]]:
        """Block on a query: -> (doc_ids [B, k], spans) where ``spans[b]``
        is the concatenated token payload of row b's retrieved docs."""
        ids_dev = self.ledger.ship_up(handle["ids"], device or self.device)
        ids = np.asarray(ids_dev)
        spans = []
        for row in ids:
            parts = [self._tokens[i, : self._tok_len[i]]
                     for i in row if i >= 0]
            span = np.concatenate(parts) if parts else \
                np.zeros((0,), np.int32)
            self.ledger.count_span(span.nbytes)
            spans.append(span.astype(np.int32))
        return ids, spans

    def replay(self, handle: Dict) -> bool:
        """Re-run the pinned selection synchronously; True iff the consumed
        ids are bit-identical (validation mode)."""
        state, t = handle["inputs"]
        _, ref = jax.block_until_ready(self._select_jit(None, state, t))
        return bool(np.array_equal(np.asarray(ref),
                                   np.asarray(handle["ids"])))

    def query_hybrid(self, terms: np.ndarray, q_embed: np.ndarray,
                     n_first: int, alpha: float = 0.5):
        """Two-stage first pass (BM25 + embedding hybrid) -> top-n_first
        (scores, ids) device arrays on the hosting device."""
        assert self.state.get("doc_embeds") is not None, \
            "hybrid retrieval needs doc embeddings in the store"
        t = self.ledger.ship_down(jnp.asarray(terms, jnp.int32), self.device)
        qe = self.ledger.ship_down(jnp.asarray(q_embed, jnp.float32),
                                   self.device)
        mix = self._hybrid_jit(self.state, t, qe, alpha=alpha)
        return jax.lax.top_k(mix, n_first)
