"""Per-slot MaC memory-bank service (paper Table 1 row 8, Fig. 6c).

The banks — FIFO segment-summary embeddings per serving slot — live on the
retrieval device together with the token-embedding table and the MaC
projection weights, so the whole prepare / relevancy / retrieve side runs
there: segment pushes ship only the segment's TOKEN IDS down, relevancy
queries ship only a token window down, and only the ``[r, d]`` retrieved
embeddings come back (spliced into the generator's context by the engine).

Segment summaries are Titans-style projections of the segment's token
embeddings (``mac.prepare_memory`` over ``L.embed`` rows): a pure function
of the slot's token stream, which is what makes the overlapped serving
schedule bit-match its synchronous counterpart.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.methods.mac import MacConfig, mac_init
from repro.hetero.transfer import TransferLedger
from repro.retrieval.select import make_retrieval_select


class MacBankService:
    def __init__(self, cfg: ArchConfig, mc: MacConfig, n_slots: int,
                 embed_params, *, key=None, device=None,
                 ledger: Optional[TransferLedger] = None):
        self.cfg, self.mc, self.n_slots = cfg, mc, n_slots
        self.device = device or jax.devices()[0]
        self.ledger = ledger or TransferLedger()
        self.sel = make_retrieval_select("mac", cfg, n_slots=n_slots, mac=mc)
        self.sp = jax.device_put(
            {"embed": embed_params,
             "mac": mac_init(key if key is not None else jax.random.PRNGKey(0),
                             cfg)},
            self.device)
        self.state = jax.device_put(self.sel.summary_init(), self.device)
        self._reset_jit = jax.jit(self.sel.reset)
        self._ingest_jit = jax.jit(self.sel.ingest)
        self._select_jit = jax.jit(self.sel.select)
        # host mirror of per-slot bank occupancy (trigger gating)
        self.counts = np.zeros((n_slots,), np.int32)

    def reset(self, slots) -> None:
        sid = jax.device_put(jnp.asarray(slots, jnp.int32), self.device)
        self.state = self._reset_jit(self.state, sid)
        self.counts[np.asarray(slots)] = 0

    def push(self, slot: int, seg_tokens: np.ndarray) -> None:
        """FIFO-push the summary of one segment's tokens into ``slot``'s
        bank (prepare stage, on-device; async dispatch)."""
        toks = self.ledger.ship_down(
            jnp.asarray(seg_tokens, jnp.int32), self.device)
        self.state = self._ingest_jit(
            self.state, self.sp, jnp.asarray(slot, jnp.int32), toks)
        self.counts[slot] = min(self.counts[slot] + 1, self.mc.memory_slots)

    def query(self, slot: int, q_tokens: np.ndarray) -> Dict:
        """Launch relevancy + retrieve for ``slot`` from a token window
        (async — collect with ``collect``)."""
        toks = self.ledger.ship_down(
            jnp.asarray(q_tokens, jnp.int32), self.device)
        state = self.state
        idx, embeds = self._select_jit(self.sp, state,
                                       toks, jnp.asarray(slot, jnp.int32))
        return {"ids": idx, "embeds": embeds, "inputs": (state, toks, slot)}

    def collect(self, handle: Dict, device=None
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Block: -> (idx [r], embeds [r', d]) with invalid picks trimmed."""
        ids_dev = self.ledger.ship_up(handle["ids"], device or self.device)
        emb_dev = self.ledger.ship_up(handle["embeds"],
                                      device or self.device)
        ids = np.asarray(ids_dev)
        embeds = np.asarray(emb_dev, np.float32)
        keep = ids >= 0
        self.ledger.count_span(embeds[keep].nbytes)
        return ids[keep], embeds[keep]

    def replay(self, handle: Dict) -> bool:
        """Re-run the pinned selection synchronously; True iff bit-equal."""
        state, toks, slot = handle["inputs"]
        ref_idx, ref_emb = jax.block_until_ready(
            self._select_jit(self.sp, state, toks,
                             jnp.asarray(slot, jnp.int32)))
        return bool(
            np.array_equal(np.asarray(ref_idx), np.asarray(handle["ids"]))
            and np.array_equal(np.asarray(ref_emb),
                               np.asarray(handle["embeds"])))
