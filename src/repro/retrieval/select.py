"""Offload-side retrieval implementations for the document-memory family.

RAG and MaC declare ``OFFLOAD_STAGES = (prepare, relevancy, retrieve)`` just
like the sparse-attention methods (paper Table 1 rows 4-6 and 8, Fig. 6b/c
data placement), but their offload-resident state is not a KV-page summary:

  rag : the corpus index — TF stats, document lengths, running document
        frequencies / IDF, doc token payloads, optional doc embeddings —
        capacity-padded so documents can be APPENDED incrementally with one
        jitted update (no re-jit while the capacity holds);
  mac : per-slot Titans/HMT memory banks — FIFO segment-summary embeddings
        plus live counts.

Both are expressed as ``hetero.select.OffloadSelect`` bundles so
``make_offload_select`` covers every OFFLOAD_STAGES declarer. The callables
keep the same roles (summary_init / reset / ingest / select) with
family-specific signatures, documented per builder; the stateful device
placement wrappers live in ``retrieval.service`` / ``retrieval.bank`` (the
analogue of ``hetero.executor`` for the sparse-attention family).

All functions are pure jnp so the services can jit them once and pin them
to the retrieval device via committed inputs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.methods.mac import MacConfig, compute_relevancy, prepare_memory
from repro.core.methods.rag import Corpus, idf_from_df
from repro.kernels import ops
from repro.models import layers as L

NEG_INF = -1e30


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _bm25_panel(s, terms):
    """Gather the query's term panel from the store state: (tfq [B, C, T],
    idf [B, T], dl/avgdl [B, C]) — the dynamic avgdl is folded into the
    doc lengths so corpus growth never re-jits the scoring path."""
    B, cap = terms.shape[0], s["doc_len"].shape[0]
    tfq = jnp.moveaxis(jnp.take(s["tf"], terms, axis=1), 1,
                       0).astype(jnp.float32)
    idf = jnp.take(s["idf"], terms, axis=0)
    dl = jnp.broadcast_to(s["doc_len"][None], (B, cap))
    avgdl = jnp.sum(s["doc_len"]) / jnp.maximum(
        s["n_docs"].astype(jnp.float32), 1.0)
    return tfq, idf, dl / avgdl


# ---------------------------------------------------------------------------
# rag — corpus index with incremental ingest + fused BM25 selection
# ---------------------------------------------------------------------------


def _rag(corpus: Corpus, *, k: int, capacity: int = 0,
         ingest_block: int = 64):
    """RAG OffloadSelect. Signatures (B = queries, C = capacity):

      summary_init()                      -> corpus state (capacity-padded)
      reset(s, slot_ids)                  -> s (corpus is global; identity)
      ingest(s, tf, dl, toks, emb, m)     -> s with ``m`` new docs appended
                                             (fixed ingest_block row count;
                                             rows >= m must be zero)
      select(sp, s, terms [B, T])         -> (scores [B, k], doc_ids [B, k])

    ``sp`` is unused (BM25 has no learned parameters) — kept for signature
    parity with the sparse-attention bundles.
    """
    from repro.hetero.select import OffloadSelect

    D0, Vr = corpus.tf.shape
    C = max(capacity or _next_pow2(D0), _next_pow2(D0))
    de = 0 if corpus.doc_embeds is None else corpus.doc_embeds.shape[1]
    dmax = corpus.doc_tokens.shape[1]
    mb = ingest_block

    def summary_init():
        pad = C - D0
        df = (corpus.tf > 0).sum(axis=0).astype(jnp.int32)
        s = {
            "tf": jnp.pad(corpus.tf, ((0, pad), (0, 0))),
            "doc_len": jnp.pad(corpus.doc_len.astype(jnp.float32), (0, pad)),
            "doc_tokens": jnp.pad(corpus.doc_tokens, ((0, pad), (0, 0))),
            "df": df,
            "idf": idf_from_df(df, D0),
            "n_docs": jnp.asarray(D0, jnp.int32),
        }
        if de:
            s["doc_embeds"] = jnp.pad(corpus.doc_embeds, ((0, pad), (0, 0)))
        return s

    def reset(s, slot_ids):
        return s

    def ingest(s, tf_new, dl_new, toks_new, emb_new, m):
        """Append up to ``ingest_block`` docs at the live watermark.
        Masked scatter-ADD onto rows that are zero by the pad invariant
        (add == set), with pad rows clipped to the last arena row where
        they add zero — a final partial block near the capacity never
        writes out of bounds, so the arena only grows when the LIVE docs
        overflow it."""
        start = s["n_docs"]
        cap = s["doc_len"].shape[0]
        live = (jnp.arange(mb) < m)
        rows = jnp.clip(start + jnp.arange(mb), 0, cap - 1)
        tf_new = tf_new * live[:, None]
        out = dict(s)
        out["tf"] = s["tf"].at[rows].add(tf_new)
        out["doc_len"] = s["doc_len"].at[rows].add(dl_new * live)
        out["doc_tokens"] = s["doc_tokens"].at[rows].add(
            toks_new * live[:, None])
        if de:
            out["doc_embeds"] = s["doc_embeds"].at[rows].add(
                emb_new * live[:, None])
        out["df"] = s["df"] + (tf_new > 0).sum(axis=0).astype(jnp.int32)
        out["n_docs"] = start + m
        out["idf"] = idf_from_df(out["df"], out["n_docs"])
        return out

    def select(sp, s, terms):
        # capacity read from the state shape: growing the arena re-traces
        # for the new static shape, appending inside it never does
        tfq, idf, dln = _bm25_panel(s, terms)
        return ops.bm25_topk(tfq, dln, idf, k,
                             block=min(4096, dln.shape[1]),
                             avgdl=1.0, valid=s["n_docs"])

    return OffloadSelect("rag", 1, k, C, summary_init, reset, ingest,
                         None, select)


def rag_hybrid_scores(s, terms, q_embed, alpha: float = 0.5):
    """Two-stage first pass on the store state: live-masked z-scored
    BM25 + dense-embedding hybrid (paper Table 1 row 5). -> [B, C]."""
    from repro.kernels import ref as kref

    C = s["tf"].shape[0]
    tfq, idf, dln = _bm25_panel(s, terms)
    lex = kref.bm25_scores(tfq, dln, idf, avgdl=1.0)
    sem = q_embed @ s["doc_embeds"].T                           # [B, C]
    live = (jnp.arange(C)[None] < s["n_docs"]).astype(jnp.float32)
    n = jnp.maximum(live.sum(-1, keepdims=True), 1.0)

    def z(x):
        x = x * live
        mu = x.sum(-1, keepdims=True) / n
        var = (((x - mu) * live) ** 2).sum(-1, keepdims=True) / n
        return (x - mu) / (jnp.sqrt(var) + 1e-6)

    mix = alpha * z(lex) + (1 - alpha) * z(sem)
    return jnp.where(live > 0, mix, NEG_INF)


# ---------------------------------------------------------------------------
# mac — per-slot FIFO memory banks of segment-summary embeddings
# ---------------------------------------------------------------------------


def _mac(cfg: ArchConfig, mc: MacConfig, n_slots: int):
    """MaC OffloadSelect. Signatures:

      summary_init()                     -> {bank [n_slots, M, d], count}
      reset(s, slot_ids)                 -> s with those banks cleared
      ingest(s, sp, slot, seg_tokens)    -> s with the segment summary
                                            FIFO-pushed into ``slot``'s bank
      select(sp, s, q_tokens [W], slot)  -> (idx [r], embeds [r, d])

    ``sp = {"embed": token embedding params, "mac": mac_init params}`` —
    segment summaries and relevancy queries are computed from token
    embeddings ON the retrieval device, so only token-id windows go down
    and only [r, d] retrieved embeddings come back (paper Fig. 6c).
    """
    from repro.hetero.select import OffloadSelect

    M, r, d = mc.memory_slots, mc.retrieve_k, cfg.d_model
    assert mc.mode == "topk", "serving bank supports topk retrieval"

    def summary_init():
        return {"bank": jnp.zeros((n_slots, M, d), jnp.float32),
                "count": jnp.zeros((n_slots,), jnp.int32)}

    def reset(s, slot_ids):
        return {"bank": s["bank"].at[slot_ids].set(0.0),
                "count": s["count"].at[slot_ids].set(0)}

    def ingest(s, sp, slot, seg_tokens):
        emb = L.embed(sp["embed"], seg_tokens[None])       # [1, S, d]
        memv = prepare_memory(sp["mac"], emb)[0]           # [d]
        row = jnp.roll(s["bank"][slot], -1, axis=0).at[-1].set(memv)
        return {"bank": s["bank"].at[slot].set(row),
                "count": s["count"].at[slot].set(
                    jnp.minimum(s["count"][slot] + 1, M))}

    def select(sp, s, q_tokens, slot):
        emb = L.embed(sp["embed"], q_tokens[None])          # [1, W, d]
        scores = compute_relevancy(sp["mac"], emb,
                                   s["bank"][slot][None])   # [1, M]
        live = jnp.arange(M)[None] < s["count"][slot]
        masked = jnp.where(live, scores, NEG_INF)
        vals, idx = jax.lax.top_k(masked, r)
        got = jnp.take_along_axis(s["bank"][slot][None],
                                  idx[..., None], axis=1)   # [1, r, d]
        idx = jnp.where(vals > NEG_INF / 2, idx, -1)
        return idx[0].astype(jnp.int32), got[0]

    return OffloadSelect("mac", mc.segment_len, r, M, summary_init, reset,
                         ingest, None, select)


# ---------------------------------------------------------------------------


def make_retrieval_select(method: str, cfg: Optional[ArchConfig] = None, *,
                          n_slots: int = 0, corpus: Optional[Corpus] = None,
                          mac: Optional[MacConfig] = None, k: int = 4,
                          capacity: int = 0, ingest_block: int = 64):
    if method == "rag":
        assert corpus is not None, "rag offload selection needs a corpus"
        return _rag(corpus, k=k, capacity=capacity,
                    ingest_block=ingest_block)
    if method == "mac":
        assert cfg is not None and mac is not None and n_slots > 0, \
            "mac offload selection needs (cfg, mac config, n_slots)"
        return _mac(cfg, mac, n_slots)
    raise KeyError(f"method {method!r} has no retrieval-side selection")
