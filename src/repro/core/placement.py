"""Placement policy: the heterogeneity analysis (paper §4, Table 2) and the
dynamic engine-selection rule (paper §5.2 / Appendix F "dynamically falls
back to GPU-only execution").

On TPU, "which engine" becomes "which execution path": fused sparse pipeline
(Pallas kernels, index-only exchange) vs dense fallback attention. The
decision is a static-shape-friendly roofline estimate evaluated at trace time
from the *maximum* context of the shape cell, plus a traced runtime predicate
for serving (lax.cond on cached length).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, MemoryConfig

# Hardware constants (TPU v5e target; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link
VMEM_BYTES = 64 * 2**20  # ~64 MiB VMEM per chip (v5e ~128MB/2 cores)

# Chip power model for the derived-energy benchmark (Table 3 analogue).
# TPU v5e ~200W peak board power; memory-bound phases draw less.
POWER_COMPUTE_W = 200.0
POWER_MEMBOUND_W = 120.0


@dataclasses.dataclass(frozen=True)
class StageCost:
    flops: float
    bytes: float

    @property
    def intensity(self) -> float:
        return self.flops / max(self.bytes, 1.0)

    @property
    def memory_bound(self) -> bool:
        """Roofline: HBM streaming, not FLOPs, sets this stage's time."""
        return self.bytes / HBM_BW >= self.flops / PEAK_FLOPS

    def seconds(self) -> float:
        return max(self.flops / PEAK_FLOPS, self.bytes / HBM_BW)

    def watts(self) -> float:
        return POWER_MEMBOUND_W if self.memory_bound else POWER_COMPUTE_W


def sparse_attention_stage_costs(cfg: ArchConfig, mem: MemoryConfig,
                                 context: int, batch: int = 1
                                 ) -> Dict[str, StageCost]:
    """Analytic per-stage cost of the sparse-attention pipeline (one layer,
    one decode step). Mirrors the paper's Table 2 / Appendix B accounting."""
    hd, kv = cfg.hd, cfg.n_kv_heads
    hi, di = mem.index_heads, mem.index_dim
    k = mem.top_k
    B = batch
    prepare = StageCost(  # index projection for the new token
        flops=2 * B * cfg.d_model * (hi * di + di),
        bytes=2 * B * (cfg.d_model * (hi * di + di)),
    )
    relevancy = StageCost(  # q_idx . k_idx over the full context
        flops=2 * B * hi * di * context,
        bytes=B * context * di * 2,  # stream compressed keys once (bf16)
    )
    retrieve = StageCost(  # top-k compare network over scores
        flops=B * context * 1.0,     # ~one compare-exchange per element
        bytes=B * context * 8,       # score + index streams
    )
    apply = StageCost(  # attention over k selected tokens
        flops=2 * B * cfg.n_heads * hd * k * 2,
        bytes=B * k * kv * hd * 2 * 2,
    )
    rest = StageCost(  # dense transformer step (projections + FFN)
        flops=2 * B * cfg.n_active_params() / cfg.n_layers,
        bytes=2 * cfg.n_active_params() / cfg.n_layers,
    )
    return {"prepare": prepare, "relevancy": relevancy, "retrieve": retrieve,
            "apply": apply, "rest": rest}


def dense_decode_cost(cfg: ArchConfig, context: int, batch: int = 1) -> StageCost:
    hd, kv = cfg.hd, cfg.n_kv_heads
    return StageCost(
        flops=2 * batch * cfg.n_heads * hd * context * 2,
        bytes=batch * context * kv * hd * 2 * 2,
    )


def in_sparse_window(context: int, mem: MemoryConfig) -> bool:
    """Host-side dynamic-fallback window (paper §5.2 / Appendix F).

    Below min_context the pipeline overhead dominates (paper Fig. 3: 1-11%
    at 4K); above fallback_context the compressed index itself spills
    (paper: >1M tokens the FPGA loses to the GPU). This is the ONE owner of
    the window; ``traced_use_sparse`` is its jit-traced twin and the hetero
    policy's ``dynamic_mode`` delegates here — keep all three aligned.
    """
    if mem.method in ("none", "ttt"):
        return False
    return mem.min_context <= context <= mem.fallback_context


def choose_path(cfg: ArchConfig, mem: MemoryConfig, context: int,
                batch: int = 1) -> str:
    """'dense' | 'sparse' — the paper's dynamic fallback, roofline-driven."""
    if not in_sparse_window(context, mem):
        return "dense"
    costs = sparse_attention_stage_costs(cfg, mem, context, batch)
    sparse_s = sum(c.seconds() for c in costs.values()) - costs["rest"].seconds()
    dense_s = dense_decode_cost(cfg, context, batch).seconds()
    return "sparse" if sparse_s < dense_s else "dense"


def traced_use_sparse(length, mem: MemoryConfig):
    """Traced form of the dynamic fallback window for jitted decode.

    ``length`` is a scalar (per-request decode) or a per-slot vector (pooled
    decode). A jitted lax.cond is batch-level, so the pooled predicate is
    decided on the max over slots — the branch itself still masks per slot.
    Returns a traced bool: take the sparse pipeline iff the (max) context
    sits inside [min_context, fallback_context].
    """
    import jax.numpy as jnp

    lmax = jnp.max(jnp.asarray(length))
    return (lmax >= mem.min_context) & (lmax <= mem.fallback_context)


# Paper Table 2 (orders of magnitude of arithmetic intensity), used by
# benchmarks to validate our measured intensities land in the right decade.
PAPER_TABLE2 = {
    "sparse_attention": {"prepare": (10, 100), "relevancy": (1, 10),
                         "retrieve": (0.1, 1), "apply": (10, 100),
                         "rest": (1, 10)},
    "rag": {"prepare": (1, 100), "relevancy": (1, 10), "retrieve": (0.1, 1),
            "apply": (0, 0), "rest": (100, 1e9)},
    "synthesized_memory": {"prepare": (1, 10), "apply": (100, 1e9),
                           "rest": (100, 1e9)},
    "memory_as_context": {"prepare": (100, 1e9), "relevancy": (1, 10),
                          "retrieve": (0.1, 1), "apply": (0, 0),
                          "rest": (100, 1e9)},
    "ttt": {"prepare": (100, 1e9), "relevancy": (1, 10),
            "apply": (100, 1e9), "rest": (100, 1e9)},
}
