"""The paper's primary contribution: the four-stage memory-processing
pipeline (pipeline.py), its placement/heterogeneity policy (placement.py),
and the concrete methods of Table 1 (methods/)."""
from repro.core.pipeline import MemoryPipeline, StageProfiler, STAGES
from repro.core import placement
