"""Memory-as-Context (Titans / HMT) — paper Table 1 row 8.

  prepare   — forward pass producing a latent memory embedding per segment
              (Titans-style linear projection of segment representations)
  relevancy — linear projection of the current segment to a query + inner
              product with the memory bank
  retrieve  — top-k memory embeddings / softmax-weighted sum
  apply     — prepend retrieved embeddings to the segment (cross-attention
              context)

Paper Fig. 6c data placement: the memory bank lives with the retrieval
engine; only retrieved embeddings move. Here the bank is sharded with the
retrieval shard_map and only [B, r, d] embeddings cross the mesh.

This module is trainable — examples/train_mac_100m.py trains a ~100M-param
backbone with it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MemoryConfig
from repro.core.pipeline import MemoryPipeline
from repro.models import layers as L

Params = Dict

# Hetero offload metadata (paper Fig. 6c): the memory bank lives with the
# retrieval engine; only retrieved embeddings move to the generator.
OFFLOAD_STAGES = ("prepare", "relevancy", "retrieve")


@dataclasses.dataclass
class MacConfig:
    segment_len: int = 1024   # paper Appendix D
    memory_slots: int = 64    # bank capacity (FIFO)
    retrieve_k: int = 8
    mode: str = "topk"        # topk | weighted (Titans weighted-sum variant)


def mac_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {
        "w_query": L.dense_init(k1, d, d, jnp.float32),
        "w_mem": L.dense_init(k2, d, d, jnp.float32),
    }


def bank_init(cfg: ArchConfig, mc: MacConfig, batch: int):
    return {
        "bank": jnp.zeros((batch, mc.memory_slots, cfg.d_model), jnp.float32),
        "count": jnp.zeros((), jnp.int32),
    }


def prepare_memory(mp: Params, segment_hidden: jnp.ndarray) -> jnp.ndarray:
    """Segment hidden states [B, S, d] -> memory embedding [B, d]."""
    return segment_hidden.astype(jnp.float32).mean(axis=1) @ mp["w_mem"]


def compute_relevancy(mp: Params, segment_embeds: jnp.ndarray,
                      bank: jnp.ndarray) -> jnp.ndarray:
    """query-gen (fusable linear proj, paper §4) + inner product -> [B, M]."""
    q = segment_embeds.astype(jnp.float32).mean(axis=1) @ mp["w_query"]
    return jnp.einsum("bd,bmd->bm", q, bank)


def retrieve(bank: jnp.ndarray, scores: jnp.ndarray, count: jnp.ndarray,
             mc: MacConfig) -> jnp.ndarray:
    """-> retrieved embeddings [B, r, d] (only these cross devices)."""
    M = bank.shape[1]
    live = jnp.arange(M)[None] < count
    masked = jnp.where(live, scores, -1e30)
    if mc.mode == "weighted":
        w = jax.nn.softmax(masked, axis=-1)
        out = jnp.einsum("bm,bmd->bd", w, bank)[:, None]
        return jnp.broadcast_to(out, (bank.shape[0], mc.retrieve_k,
                                      bank.shape[2]))
    _, idx = jax.lax.top_k(masked, mc.retrieve_k)
    return jnp.take_along_axis(bank, idx[..., None], axis=1)


def push(bank_state: Dict, new_mem: jnp.ndarray) -> Dict:
    """FIFO append of the new segment memory."""
    bank = jnp.roll(bank_state["bank"], -1, axis=1).at[:, -1].set(new_mem)
    return {"bank": bank,
            "count": jnp.minimum(bank_state["count"] + 1,
                                 bank_state["bank"].shape[1])}


def segment_step(mp: Params, bank_state: Dict, segment_embeds: jnp.ndarray,
                 mc: MacConfig) -> Tuple[jnp.ndarray, Dict]:
    """Full pipeline for one segment.

    segment_embeds [B, S, d] (token embeddings) -> (context [B, r+S, d],
    updated bank). The caller runs the backbone on `context` and then calls
    ``prepare_memory`` + ``push`` with the resulting hidden states.
    """
    scores = compute_relevancy(mp, segment_embeds, bank_state["bank"])
    got = retrieve(bank_state["bank"], scores, bank_state["count"], mc)
    context = jnp.concatenate([got.astype(segment_embeds.dtype),
                               segment_embeds], axis=1)
    return context, bank_state


def build_pipeline(mp: Params, mc: MacConfig) -> MemoryPipeline:
    """Stage descriptor over M = (segment_hidden, bank_state), x = segment
    embeddings. The relevancy stage computes the bank scores ONCE and the
    retrieve stage consumes them (S flows between stages per Definition
    3.1), so the Fig.-3 stage profiler attributes score time to relevancy
    and only the gather to retrieve."""

    def prepare(M):
        hidden, bank_state = M
        # new segment memory for the post-step push; the bank rides along
        # so relevancy can score against it
        return (prepare_memory(mp, hidden), bank_state)

    def relevancy(I, seg):
        _, bank_state = I
        return compute_relevancy(mp, seg, bank_state["bank"])

    def retrieve_stage(M, S):
        _, bank_state = M
        return retrieve(bank_state["bank"], S, bank_state["count"], mc)

    def apply(got, seg):
        return jnp.concatenate([got.astype(seg.dtype), seg], axis=1)

    return MemoryPipeline(name="mac", prepare=prepare, relevancy=relevancy,
                          retrieve=retrieve_stage, apply=apply)
