"""Test-time training (TTT / LaCT) — paper Table 1 row 9.

  prepare   — backward pass (fast-weight gradient step over a chunk)
  relevancy — compute reconstruction loss
  retrieve  — N/A (parameterized memory, bypassed)
  apply     — forward pass through the updated fast weights

Paper §4: "the heterogeneity is insufficient ... we do NOT deploy it on the
heterogeneous system". We mirror that: this layer always runs the dense path
(no kernels, no offload) — implemented so the profiler can still measure its
stage breakdown for Fig. 5 / Table 2.

LaCT-style batched (chunked) update: W <- W - lr * phi(K)^T (phi(K) W - V).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.pipeline import MemoryPipeline
from repro.models import layers as L

Params = Dict

# Hetero offload metadata: paper §4 — "we do NOT deploy it on the
# heterogeneous system"; every stage stays on the main device.
OFFLOAD_STAGES = ()


def ttt_init(key, cfg: ArchConfig, fast_dim: int = 0) -> Params:
    d = cfg.d_model
    f = fast_dim or d
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], d, f, jnp.float32),
        "wk": L.dense_init(ks[1], d, f, jnp.float32),
        "wv": L.dense_init(ks[2], d, f, jnp.float32),
        "out": L.dense_init(ks[3], f, d, jnp.float32),
        "lr": jnp.asarray(0.1, jnp.float32),
    }


def fast_state_init(cfg: ArchConfig, batch: int, fast_dim: int = 0):
    f = fast_dim or cfg.d_model
    return jnp.zeros((batch, f, f), jnp.float32)


def ttt_forward(p: Params, x: jnp.ndarray, state: jnp.ndarray,
                chunk: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, d]; state W [B, f, f] -> (y [B, S, d], W')."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    xf = x.astype(jnp.float32)
    q = jax.nn.silu(xf @ p["wq"]).reshape(B, nc, chunk, -1)
    k = jax.nn.silu(xf @ p["wk"]).reshape(B, nc, chunk, -1)
    v = (xf @ p["wv"]).reshape(B, nc, chunk, -1)

    def step(W, inp):
        qc, kc, vc = inp  # [B, chunk, f]
        # relevancy: reconstruction residual (loss gradient)
        resid = jnp.einsum("bcf,bfg->bcg", kc, W) - vc
        # prepare: batched gradient step on the fast weights (LaCT)
        W = W - p["lr"] / chunk * jnp.einsum("bcf,bcg->bfg", kc, resid)
        # apply: forward through updated weights
        y = jnp.einsum("bcf,bfg->bcg", qc, W)
        return W, y

    tos = lambda a: jnp.moveaxis(a, 1, 0)
    state, ys = jax.lax.scan(step, state, (tos(q), tos(k), tos(v)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, -1)
    return (y @ p["out"]).astype(x.dtype), state


def build_pipeline(p: Params, chunk: int = 256) -> MemoryPipeline:
    def prepare(M):
        W, kc, vc = M
        resid = jnp.einsum("bcf,bfg->bcg", kc, W) - vc
        return W - p["lr"] / kc.shape[1] * jnp.einsum("bcf,bcg->bfg", kc, resid)

    def relevancy(W, x):
        kc, vc = x
        resid = jnp.einsum("bcf,bfg->bcg", kc, W) - vc
        return 0.5 * jnp.mean(resid * resid)

    def apply(Mp, x):
        W = Mp if isinstance(Mp, jnp.ndarray) else Mp[0]
        qc = x[0] if isinstance(x, tuple) else x
        return jnp.einsum("bcf,bfg->bcg", qc, W)

    return MemoryPipeline(name="ttt", prepare=prepare, relevancy=relevancy,
                          retrieve=None, apply=apply)
