"""MemAgent (synthesized memory) — paper Table 1 row 7.

  prepare   — MODEL DECODING: generate a textual memory of ``mem_len`` tokens
              conditioned on (previous memory, current segment)
  relevancy — N/A (bypassed; always uses the preceding segment's memory)
  retrieve  — nearest (previous) memory — a copy, no math
  apply     — MODEL PREFILLING: consume [memory; next segment]

Prefill/decode disaggregation (paper Fig. 6b): ``prefill_fn`` and
``decode_fn`` are injected so the serving engine can place them on different
mesh roles (the paper's GPU-prefill / FPGA-decode split becomes a
prefill-submesh / decode-submesh split, DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MemoryConfig
from repro.core.pipeline import MemoryPipeline

# Hetero offload metadata: both active stages ARE model passes (decode /
# prefill) — nothing leaves the compute engine.
OFFLOAD_STAGES = ()


@dataclasses.dataclass
class MemAgentConfig:
    segment_len: int = 5000   # paper Appendix D
    mem_len: int = 1024
    max_answer: int = 32


def run_memagent(
    params,
    cfg: ArchConfig,
    doc_tokens: jnp.ndarray,   # [B, n_seg * segment_len]
    question: jnp.ndarray,     # [B, q_len]
    ma: MemAgentConfig,
    *,
    prefill_fn: Callable,      # (params, tokens, max_len) -> (logits, caches)
    decode_fn: Callable,       # (params, token, caches) -> (logits, caches)
    profiler=None,
):
    """Segment loop -> answer tokens [B, max_answer]."""
    import time as _t
    B, total = doc_tokens.shape
    n_seg = total // ma.segment_len
    memory = jnp.zeros((B, ma.mem_len), jnp.int32)  # empty textual memory

    def synthesize(memory, segment):
        """prepare-memory: decode mem_len tokens from [memory; segment]."""
        ctx = jnp.concatenate([memory, segment], axis=1)
        logits, caches = prefill_fn(params, ctx,
                                    ctx.shape[1] + ma.mem_len)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = []
        for _ in range(ma.mem_len):
            out.append(tok)
            logits, caches = decode_fn(params, tok, caches)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return jnp.stack(out, axis=1)  # [B, mem_len]

    for s in range(n_seg):
        seg = jax.lax.dynamic_slice_in_dim(doc_tokens, s * ma.segment_len,
                                           ma.segment_len, axis=1)
        t0 = _t.perf_counter()
        memory = jax.block_until_ready(synthesize(memory, seg))
        if profiler:  # decoding-to-memory == prepare (paper App. B)
            profiler.record("memagent", ("prepare",), _t.perf_counter() - t0)

    # answer: prefill [memory; question], decode up to max_answer
    ctx = jnp.concatenate([memory, question], axis=1)
    t0 = _t.perf_counter()
    logits, caches = prefill_fn(params, ctx, ctx.shape[1] + ma.max_answer)
    if profiler:
        profiler.record("memagent", ("apply",), _t.perf_counter() - t0)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    answer = [tok]
    for _ in range(ma.max_answer - 1):
        logits, caches = decode_fn(params, tok, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        answer.append(tok)
    return jnp.stack(answer, axis=1)


def build_pipeline(synthesize_fn, prefill_fn) -> MemoryPipeline:
    return MemoryPipeline(
        name="memagent",
        prepare=lambda M: synthesize_fn(M),   # model decoding
        relevancy=None,                        # bypassed (paper §3.1)
        retrieve=lambda M, S: S,               # nearest = previous memory
        apply=lambda Mp, x: prefill_fn(Mp, x),
    )
