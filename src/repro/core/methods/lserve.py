"""LServe — paper Table 1 row 3.

  prepare   — page-wise min/max pooling of the key cache (Pallas page_pool
              kernel); logical pages grouped into physical pages
  relevancy — per-channel max(q*min, q*max) bound, max-reduced over logical
              pages within each physical page
  retrieve  — top-k physical pages
  apply     — block-sparse attention over the logical pages of the selected
              physical pages (+ optional sliding-window locality, Mixtral)
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MemoryConfig
from repro.core.pipeline import MemoryPipeline
from repro.kernels import ops, ref as kref

Params = Dict

# Hetero offload metadata: the page min/max summaries are the only inputs
# to relevancy/retrieve; sparse apply stays with the KV pool.
OFFLOAD_STAGES = ("prepare", "relevancy", "retrieve")


def lserve_init(key, cfg: ArchConfig, mem: MemoryConfig, stacked: bool = True):
    # LServe's prepare/relevancy are projection-free (min/max pooling of raw
    # keys) — no learned parameters; a dummy leaf keeps the scan signature.
    n = cfg.n_layers if stacked else 1
    return {"_": jnp.zeros((n,), jnp.int32)} if stacked else {"_": jnp.zeros((), jnp.int32)}


def _physical_scores(q, pmin, pmax, ppp: int):
    """Logical page scores max-reduced to physical pages. -> [B, n_phys]."""
    sc = kref.lserve_page_scores(q, pmin, pmax)  # [B, n_logical]
    B, nl = sc.shape
    pad = (-nl) % ppp
    if pad:
        sc = jnp.pad(sc, ((0, 0), (0, pad)), constant_values=-1e30)
    return sc.reshape(B, (nl + pad) // ppp, ppp).max(axis=-1)


def make_sparse_fn(cfg: ArchConfig, mem: MemoryConfig, *, tp: int = 16):
    ps = mem.block_size                   # logical page size
    ppp = mem.pages_per_physical
    n_phys_sel = max(mem.token_budget // (ps * ppp), 1)

    def sparse_fn(q, kc, vc, length, sp, k_new=None):
        B = q.shape[0]
        S = kc.shape[1]
        # prepare: page min/max pooling (Pallas kernel)
        pmin, pmax = ops.page_minmax(kc, page_size=ps)
        pmin = pmin.max(axis=2)  # reduce kv-head dim for the bound
        pmax = pmax.max(axis=2)
        # relevancy (bound) + retrieve top physical pages
        sc = _physical_scores(q[:, 0], pmin[:, :, None], pmax[:, :, None], ppp)
        n_sel = min(n_phys_sel, sc.shape[1])  # small caches: select them all
        _, phys = jax.lax.top_k(sc, n_sel)                 # [B, n_sel]
        # expand to logical pages
        logical = (phys[..., None] * ppp +
                   jnp.arange(ppp)[None, None, :]).reshape(B, -1)
        lb = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
        live = (logical * ps < lb[:, None]) & (logical < S // ps)
        logical = jnp.where(live, logical, -1)
        from repro.core.methods.dsa import strip_dead_heads, repad_dead_heads
        out, _ = ops.paged_decode_attention(
            strip_dead_heads(q, cfg), kc, vc, logical.astype(jnp.int32), lb,
            page_size=ps)
        return repad_dead_heads(out, q, cfg)

    return sparse_fn


def build_pipeline(cfg: ArchConfig, mem: MemoryConfig, sp: Params, *,
                   fused: bool = False) -> MemoryPipeline:
    ps = mem.block_size
    ppp = mem.pages_per_physical
    n_phys_sel = max(mem.token_budget // (ps * ppp), 1)

    def prepare(M):
        kc, _ = M
        if fused:
            pmin, pmax = ops.page_minmax(kc, page_size=ps)
        else:
            pmin, pmax = kref.page_minmax(kc, ps)
        return pmin.max(axis=2), pmax.max(axis=2)

    def relevancy(I, q):
        pmin, pmax = I
        return _physical_scores(q[:, 0], pmin[:, :, None], pmax[:, :, None], ppp)

    def retrieve(M, sc):
        kc, vc = M
        _, phys = jax.lax.top_k(sc, n_phys_sel)
        B = sc.shape[0]
        logical = (phys[..., None] * ppp +
                   jnp.arange(ppp)[None, None, :]).reshape(B, -1)
        return (kc, vc, logical)

    def apply(Mp, q):
        kc, vc, logical = Mp
        B = q.shape[0]
        length = jnp.full((B,), kc.shape[1], jnp.int32)
        out, _ = ops.paged_decode_attention(
            q[:, 0], kc, vc, logical.astype(jnp.int32), length, page_size=ps)
        return out

    return MemoryPipeline(
        name="lserve-fused" if fused else "lserve",
        prepare=prepare, relevancy=relevancy, retrieve=retrieve, apply=apply,
    )
