"""SeerAttention-R — paper Table 1 row 2.

  prepare   — linear down-projection of queries + average pooling of keys
              over blocks (block 64)
  relevancy — inner product (pooled q . pooled k per block)
  retrieve  — top-k blocks (token budget 4096) OR threshold (5e-4 on
              softmax-normalized block scores)
  apply     — block-sparse attention over selected blocks

Threshold mode keeps static shapes: the engine still materializes
``budget/block`` slots but invalidates (-1) every block whose normalized
score is below the threshold — matching the paper's variable-sparsity
semantics with TPU-legal shapes.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MemoryConfig
from repro.core.pipeline import MemoryPipeline
from repro.kernels import ops
from repro.models import layers as L

Params = Dict

# Hetero offload metadata: gate pooling + block scoring touch only the
# pooled gate cache; block-sparse apply stays with the KV pool.
OFFLOAD_STAGES = ("prepare", "relevancy", "retrieve")


def seer_init(key, cfg: ArchConfig, mem: MemoryConfig, stacked: bool = True):
    hd = cfg.hd
    hp_in = cfg.n_heads * hd
    kv_in = cfg.n_kv_heads * hd

    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "wq_gate": L.dense_init(k1, hp_in, mem.index_dim, jnp.bfloat16),
            "wk_gate": L.dense_init(k2, kv_in, mem.index_dim, jnp.bfloat16),
        }

    n = cfg.n_layers if stacked else 1
    p = jax.vmap(one)(jax.random.split(key, n))
    return p if stacked else jax.tree.map(lambda a: a[0], p)


def make_sparse_fn(cfg: ArchConfig, mem: MemoryConfig, *, tp: int = 16):
    bs = mem.block_size
    n_sel = max(mem.token_budget // bs, 1)

    def sparse_fn(q, kc, vc, length, sp, k_new=None):
        B = q.shape[0]
        S = kc.shape[1]
        # prepare: pooled block keys + gated query
        k_gate = (kc.reshape(B, S, -1) @ sp["wk_gate"])
        k_blk = k_gate.reshape(B, S // bs, bs, -1).mean(axis=2)  # [B,nb,di]
        q_gate = (q[:, 0].reshape(B, -1) @ sp["wq_gate"])[:, None, :]  # [B,1,di]
        w = jnp.ones((B, 1), jnp.float32)
        # fused relevancy + retrieve (top-k blocks)
        vals, bidx = ops.relevancy_topk(
            q_gate, k_blk, w, n_sel, block=max(min(4096, S // bs), n_sel))
        lb = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
        live = bidx * bs < lb[:, None]
        if mem.selection == "threshold":
            # normalize: block softmax over selected candidates, drop < tau
            probs = jax.nn.softmax(vals, axis=-1)
            live &= probs >= mem.threshold
        bidx = jnp.where(live, bidx, -1)
        from repro.core.methods.dsa import strip_dead_heads, repad_dead_heads
        out, _ = ops.paged_decode_attention(
            strip_dead_heads(q, cfg), kc, vc, bidx.astype(jnp.int32), lb,
            page_size=bs)
        return repad_dead_heads(out, q, cfg)

    return sparse_fn


def build_pipeline(cfg: ArchConfig, mem: MemoryConfig, sp: Params, *,
                   fused: bool = False) -> MemoryPipeline:
    from repro.kernels import ref as kref
    bs = mem.block_size
    n_sel = max(mem.token_budget // bs, 1)

    def prepare(M):
        kc, _ = M
        B, S = kc.shape[0], kc.shape[1]
        kg = kc.reshape(B, S, -1) @ sp["wk_gate"]
        return kg.reshape(B, S // bs, bs, -1).mean(axis=2)

    def relevancy(k_blk, q):
        B = q.shape[0]
        qg = (q[:, 0].reshape(B, -1) @ sp["wq_gate"])[:, None, :]
        w = jnp.ones((B, 1), jnp.float32)
        if fused:
            _, bidx = ops.relevancy_topk(
                qg, k_blk, w, n_sel, block=max(min(4096, k_blk.shape[1]), n_sel))
            return ("fused", bidx)
        return ("scores", kref.relevancy_scores(qg, k_blk, w))

    def retrieve(M, S):
        kc, vc = M
        tag, val = S
        if tag == "fused":
            return (kc, vc, val)
        _, bidx = jax.lax.top_k(val, n_sel)
        return (kc, vc, bidx)

    def apply(Mp, q):
        kc, vc, bidx = Mp
        B = q.shape[0]
        length = jnp.full((B,), kc.shape[1], jnp.int32)
        out, _ = ops.paged_decode_attention(
            q[:, 0], kc, vc, bidx.astype(jnp.int32), length, page_size=bs)
        return out

    return MemoryPipeline(
        name="seer-fused" if fused else "seer",
        prepare=prepare, relevancy=relevancy, retrieve=retrieve, apply=apply,
        fused={"relevancy": ("relevancy", "retrieve")} if fused else {},
    )
