"""DeepSeek Sparse Attention (lightning indexer) — paper Table 1 row 1.

Pipeline:
  prepare   — project hidden/KV into compact index vectors (+ partial RoPE)
  relevancy — 64-head inner product, per-head ReLU, query-weighted sum
  retrieve  — top-k tokens (k = 2048)
  apply     — attention restricted to the retrieved tokens

TPU adaptation: retrieval is quantized to micro-pages of ``page`` tokens
(default 16) so the apply stage gathers page-aligned DMA blocks (the paper's
own LServe/SeerAttention rows make the same granularity trade). Token-exact
mode (page=1) is kept for parity tests. Relevancy+retrieval run in the fused
Pallas kernel (FPGA General Setup analogue).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MemoryConfig
from repro.core.pipeline import MemoryPipeline
from repro.kernels import ops
from repro.models import layers as L

Params = Dict

# Stages the hetero subsystem may move off the KV-owning device (paper
# §5.2): the indexer reads only compressed index vectors; apply gathers raw
# KV pages and must stay with the pool.
OFFLOAD_STAGES = ("prepare", "relevancy", "retrieve")


def dsa_init(key, cfg: ArchConfig, mem: MemoryConfig, stacked: bool = True):
    """Per-layer lightning-indexer params, stacked [L, ...] for the scan."""
    hd = cfg.hd
    hp_in = cfg.n_heads * hd  # from query heads (pre-o-proj activations)
    kv_in = cfg.n_kv_heads * hd

    def one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "wq_idx": L.dense_init(k1, hp_in, mem.index_heads * mem.index_dim,
                                   jnp.bfloat16),
            "wk_idx": L.dense_init(k2, kv_in, mem.index_dim, jnp.bfloat16),
            "w_wgt": L.dense_init(k3, hp_in, mem.index_heads, jnp.float32,
                                  scale=0.02),
        }

    n = cfg.n_layers if stacked else 1
    keys = jax.random.split(key, n)
    p = jax.vmap(one)(keys)
    return p if stacked else jax.tree.map(lambda a: a[0], p)


def _index_qkw(sp: Params, q: jnp.ndarray, k_cache: jnp.ndarray,
               mem: MemoryConfig):
    """prepare: q [B,1orHp,hd...] flattened; k_cache [B,S,KV,hd] -> index
    tensors (q_idx [B,Hi,di], k_idx [B,S,di], w [B,Hi])."""
    B = q.shape[0]
    S = k_cache.shape[1]
    qf = q.reshape(B, -1)
    n_in = sp["wq_idx"].shape[0]
    qf = qf[:, :n_in]
    q_idx = (qf @ sp["wq_idx"]).reshape(B, -1, sp["wk_idx"].shape[1])
    k_idx = k_cache.reshape(B, S, -1) @ sp["wk_idx"]
    w = jax.nn.softmax((qf.astype(jnp.float32) @ sp["w_wgt"]), axis=-1)
    return q_idx, k_idx, w


def strip_dead_heads(q: jnp.ndarray, cfg: ArchConfig):
    """[B, 1, Hp, hd] -> [B, n_heads, hd]: drop TP dead-head padding before
    the paged attention kernel (it requires Hq % KV == 0; dead heads are
    zero-masked afterwards anyway)."""
    return q[:, 0, : cfg.n_heads]


def repad_dead_heads(out: jnp.ndarray, q_like: jnp.ndarray, cfg: ArchConfig):
    """[B, n_heads, hd] -> [B, 1, Hp, hd] (zeros in the dead-head slots)."""
    B, _, HP, hd = q_like.shape
    pad = HP - cfg.n_heads
    if pad:
        out = jnp.pad(out, ((0, 0), (0, pad), (0, 0)))
    return out.astype(q_like.dtype)[:, None]


def make_sparse_fn(cfg: ArchConfig, mem: MemoryConfig, *, tp: int = 16,
                   page: int = 16, max_context: int = 0):
    """Returns sparse_fn(q, kc, vc, length, sp) for model.decode_step."""
    from repro.models import attention as A

    n_pages_sel = max(mem.top_k // page, 1)

    def sparse_fn(q, kc, vc, length, sp, k_new=None):
        B, _, HP, hd = q.shape
        S = kc.shape[1]
        # --- prepare (index projection of query + cached keys) ---
        q_idx, k_idx, w = _index_qkw(sp, q[:, 0], kc, mem)
        # --- fused relevancy + retrieve (Pallas kernel) ---
        # page-level scores: max-pool token scores to micro-pages via
        # scoring pooled keys (mean-pooled index vectors per page)
        kp = k_idx.reshape(B, S // page, page, -1).mean(axis=2)
        vals, pidx = ops.relevancy_topk(
            q_idx, kp, w, n_pages_sel,
            block=max(min(4096, S // page), n_pages_sel))
        # mask pages beyond the live context (length is [] or per-slot [B])
        lb = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
        live = pidx * page < lb[:, None]
        pidx = jnp.where(live, pidx, -1)
        # --- apply: paged sparse attention over retrieved pages ---
        out, _ = ops.paged_decode_attention(
            strip_dead_heads(q, cfg), kc, vc, pidx.astype(jnp.int32), lb,
            page_size=page)
        return repad_dead_heads(out, q, cfg)  # [B,1,Hp,hd]

    return sparse_fn


def make_sparse_fn_distributed(cfg: ArchConfig, mem: MemoryConfig, mesh, *,
                               axis="model", batch_axis=None, tp: int = 16,
                               page: int = 64):
    """Sequence-parallel sparse decode (the beyond-paper optimized path):
    shard_map distributed top-k (index-only exchange) + per-shard paged
    attention with LSE merge. See distributed/topk.py."""
    from repro.distributed.topk import (distributed_relevancy_topk,
                                        distributed_sparse_decode)

    n_pages_sel = max(mem.top_k // page, 1)

    def sparse_fn(q, kc, vc, length, sp, k_new=None):
        B = q.shape[0]
        S = kc.shape[1]
        q_idx, k_idx, w = _index_qkw(sp, q[:, 0], kc, mem)
        kp = k_idx.reshape(B, S // page, page, -1).mean(axis=2)
        vals, pidx = distributed_relevancy_topk(
            q_idx, kp, w, n_pages_sel, mesh, axis, block=2048,
            batch_axis=batch_axis)
        lb = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
        live = pidx * page < lb[:, None]
        pidx = jnp.where(live, pidx, -1)
        out = distributed_sparse_decode(
            strip_dead_heads(q, cfg), kc, vc, pidx.astype(jnp.int32), lb,
            mesh, axis, page_size=page, batch_axis=batch_axis)
        return repad_dead_heads(out, q, cfg)

    return sparse_fn


def idx_cache_init(cfg: ArchConfig, mem: MemoryConfig, batch: int,
                   max_len: int, *, page: int = 64, stacked: bool = True):
    """Incremental pooled-index cache: per-page SUM of index vectors (the
    mean is recovered at score time from `length`). Prepare-memory runs once
    per token instead of re-projecting the whole context every step."""
    n_pages = max_len // page
    shape = (batch, n_pages, mem.index_dim)
    if stacked:
        shape = (cfg.n_layers,) + shape
    return jnp.zeros(shape, jnp.float32)


def make_sparse_fn_cached(cfg: ArchConfig, mem: MemoryConfig, mesh, *,
                          axis="model", batch_axis=None, tp: int = 16,
                          page: int = 64):
    """Stateful sequence-parallel sparse decode (§Perf iteration 3):
    sparse_params = {"p": indexer weights, "kidx_sum": pooled index cache}.
    Per step: project ONLY the new token's key into the index, update one
    page of the cache, score the 128-dim compressed index (not the raw KV),
    distributed top-k + LSE-merged paged attention.
    """
    from repro.distributed.topk import (distributed_relevancy_topk,
                                        distributed_sparse_decode)

    n_pages_sel = max(mem.top_k // page, 1)

    def sparse_fn(q, kc, vc, length, sp, k_new=None):
        B = q.shape[0]
        S = kc.shape[1]
        p, kidx_sum = sp["p"], sp["kidx_sum"]
        # --- prepare (incremental): index the ONE new key. k_new is the key
        # computed THIS step (replicated) — slicing it back out of the
        # seq-sharded cache forces a full-cache all-gather (refuted
        # iteration, §Perf log). The page update is shard-local. ---
        k_idx_new = (k_new.reshape(B, -1) @ p["wk_idx"]).astype(jnp.float32)
        from repro.distributed.topk import sharded_page_add
        kidx_sum = sharded_page_add(kidx_sum, k_idx_new, (length - 1) // page,
                                    mesh, axis, batch_axis=batch_axis)
        # --- relevancy over the compressed pooled index ---
        qf = q[:, 0].reshape(B, -1)[:, : p["wq_idx"].shape[0]]
        q_idx = (qf @ p["wq_idx"]).reshape(B, -1, p["wk_idx"].shape[1])
        w = jax.nn.softmax(qf.astype(jnp.float32) @ p["w_wgt"], axis=-1)
        n_pages = kidx_sum.shape[1]
        counts = jnp.clip(length - jnp.arange(n_pages) * page, 0, page)
        kp = kidx_sum * (1.0 / jnp.maximum(counts, 1))[None, :, None]
        vals, pidx = distributed_relevancy_topk(
            q_idx, kp, w, n_pages_sel, mesh, axis, block=2048,
            batch_axis=batch_axis)
        live = pidx * page < length
        pidx = jnp.where(live, pidx, -1)
        lb = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
        out = distributed_sparse_decode(
            strip_dead_heads(q, cfg), kc, vc, pidx.astype(jnp.int32), lb,
            mesh, axis, page_size=page, batch_axis=batch_axis)
        return repad_dead_heads(out, q, cfg), dict(sp, kidx_sum=kidx_sum)

    return sparse_fn


def build_pipeline(cfg: ArchConfig, mem: MemoryConfig, sp: Params, *,
                   page: int = 16, fused: bool = False) -> MemoryPipeline:
    """Concrete 4-stage pipeline over (memory=(kc, vc), query=q [B,1,Hp,hd]).

    ``fused=False`` runs each stage as separate XLA ops (the paper's GPU
    baseline); ``fused=True`` routes relevancy+retrieval through the fused
    Pallas kernel (the FPGA analogue). Benchmarks compare the two — the
    structural reproduction of paper Fig. 9.
    """
    from repro.kernels import ref as kref

    n_pages_sel = max(mem.top_k // page, 1)

    def prepare(M):
        kc, vc = M
        B, S = kc.shape[0], kc.shape[1]
        k_idx = kc.reshape(B, S, -1) @ sp["wk_idx"]
        return k_idx.reshape(B, S // page, page, -1).mean(axis=2)  # pooled

    def relevancy(kp, q):
        B = q.shape[0]
        qf = q[:, 0].reshape(B, -1)[:, : sp["wq_idx"].shape[0]]
        q_idx = (qf @ sp["wq_idx"]).reshape(B, -1, sp["wk_idx"].shape[1])
        w = jax.nn.softmax(qf.astype(jnp.float32) @ sp["w_wgt"], axis=-1)
        if fused:
            vals, pidx = ops.relevancy_topk(
                q_idx, kp, w, n_pages_sel,
                block=max(min(4096, kp.shape[1]), n_pages_sel))
            return ("fused", pidx)
        return ("scores", kref.relevancy_scores(q_idx, kp, w))

    def retrieve(M, S):
        """ret(M, S) = M' — the refined memory is (KV, selected page ids)."""
        kc, vc = M
        tag, val = S
        if tag == "fused":
            return (kc, vc, val)
        _, pidx = jax.lax.top_k(val, n_pages_sel)
        return (kc, vc, pidx)

    def apply(Mp, q):
        kc, vc, pidx = Mp
        B = q.shape[0]
        length = jnp.full((B,), kc.shape[1], jnp.int32)
        out, _ = ops.paged_decode_attention(
            q[:, 0], kc, vc, pidx.astype(jnp.int32), length, page_size=page)
        return out

    pipe = MemoryPipeline(
        name="dsa-fused" if fused else "dsa",
        prepare=prepare, relevancy=relevancy, retrieve=retrieve, apply=apply,
        fused={"relevancy": ("relevancy", "retrieve")} if fused else {},
    )
    return pipe
