"""RAG — paper Table 1 rows 4-6 (two-stage, fixed-sentence, dynamic).

  prepare   — corpus indexing: term-frequency stats + doc embeddings
              (one-time, amortized — paper §3.1)
  relevancy — BM25 (single-stage) or hybrid BM25+embedding then a
              cross-encoder reranker (two-stage)
  retrieve  — top-k documents
  apply     — append retrieved documents to the query (no FLOPs; paper
              Table 2 marks this stage "no calculations")

Dynamic-RAG trigger policies (DRAGIN-style attention-uncertainty, FLARE-style
confidence) are implemented over the generator's decode logits.

TPU adaptation: BM25's per-term histogram walk is re-blocked — the query's
term columns are gathered once into a dense [D, T] panel (host/XLA gather),
then the fused Pallas kernel streams score+top-k (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MemoryConfig
from repro.core.pipeline import MemoryPipeline
from repro.kernels import ops, ref as kref

# Hetero offload metadata: the document index (TF stats, embeddings) lives
# with the retrieval engine; apply is pure prompt assembly on the generator.
OFFLOAD_STAGES = ("prepare", "relevancy", "retrieve")


@dataclasses.dataclass
class Corpus:
    """Dense retrieval-side corpus statistics (synthetic Zipf, data/)."""

    tf: jnp.ndarray        # [D, Vr] term frequencies (int32)
    doc_len: jnp.ndarray   # [D]
    idf: jnp.ndarray       # [Vr]
    doc_tokens: jnp.ndarray  # [D, doc_max] generator-vocab token ids
    doc_embeds: Optional[jnp.ndarray] = None  # [D, de] (two-stage)

    @property
    def n_docs(self) -> int:
        return self.tf.shape[0]

    @property
    def avgdl(self) -> float:
        return float(jnp.mean(self.doc_len))


def idf_from_df(df, n_docs):
    """BM25 idf from document frequencies (the one smoothing formula —
    shared by corpus building, slicing, and the serving store's running
    refresh)."""
    nf = jnp.asarray(n_docs).astype(jnp.float32)
    dff = jnp.asarray(df).astype(jnp.float32)
    return jnp.log((nf - dff + 0.5) / (dff + 0.5) + 1.0)


def corpus_slice(corpus: Corpus, lo: int, hi: int) -> Corpus:
    """Row slice [lo, hi) as a standalone Corpus — the unit of incremental
    ingest into the serving-side ``retrieval.RetrievalService`` (its store
    recomputes df/idf over the running corpus, so the slice's own idf is
    only a local best-effort)."""
    tf = corpus.tf[lo:hi]
    idf = idf_from_df((tf > 0).sum(axis=0), tf.shape[0])
    return Corpus(
        tf=tf, doc_len=corpus.doc_len[lo:hi], idf=idf,
        doc_tokens=corpus.doc_tokens[lo:hi],
        doc_embeds=None if corpus.doc_embeds is None
        else corpus.doc_embeds[lo:hi])


def gather_term_panel(corpus: Corpus, query_terms: jnp.ndarray):
    """query_terms [B, T] -> (tf_panel [B, D, T], idf [B, T]).

    The one irregular gather, hoisted out of the kernel."""
    tfq = jnp.take(corpus.tf, query_terms, axis=1)      # [D, B, T]
    tfq = jnp.moveaxis(tfq, 1, 0).astype(jnp.float32)   # [B, D, T]
    idf = jnp.take(corpus.idf, query_terms, axis=0)     # [B, T]
    return tfq, idf


def bm25_retrieve(corpus: Corpus, query_terms: jnp.ndarray, k: int,
                  *, fused: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (scores [B,k], doc_ids [B,k])."""
    tfq, idf = gather_term_panel(corpus, query_terms)
    B, D, T = tfq.shape
    dl = jnp.broadcast_to(corpus.doc_len[None].astype(jnp.float32), (B, D))
    if fused:
        return ops.bm25_topk(tfq, dl, idf, k, block=min(4096, D),
                             avgdl=corpus.avgdl)
    return kref.bm25_topk(tfq, dl, idf, k, avgdl=corpus.avgdl)


def hybrid_retrieve(corpus: Corpus, query_terms: jnp.ndarray,
                    query_embed: jnp.ndarray, n_first: int,
                    alpha: float = 0.5):
    """Two-stage first pass: BM25 + dense-embedding hybrid -> top-N."""
    tfq, idf = gather_term_panel(corpus, query_terms)
    B, D, _ = tfq.shape
    dl = jnp.broadcast_to(corpus.doc_len[None].astype(jnp.float32), (B, D))
    lex = kref.bm25_scores(tfq, dl, idf, avgdl=corpus.avgdl)
    sem = query_embed @ corpus.doc_embeds.T             # [B, D]
    z = lambda s: (s - s.mean(-1, keepdims=True)) / (s.std(-1, keepdims=True) + 1e-6)
    return jax.lax.top_k(alpha * z(lex) + (1 - alpha) * z(sem), n_first)


def rerank(score_fn, corpus: Corpus, query_tokens: jnp.ndarray,
           cand_ids: jnp.ndarray, k: int):
    """Cross-encoder second stage. score_fn(query_tokens, doc_tokens)->[B,N]."""
    docs = jnp.take(corpus.doc_tokens, cand_ids, axis=0)  # [B, N, doc_max]
    scores = score_fn(query_tokens, docs)
    top, pos = jax.lax.top_k(scores, k)
    return top, jnp.take_along_axis(cand_ids, pos, axis=1)


def append_to_query(corpus: Corpus, query_tokens: jnp.ndarray,
                    doc_ids: jnp.ndarray, max_len: int):
    """Apply-to-inference: concat retrieved docs before the query (no math)."""
    B, k = doc_ids.shape
    docs = jnp.take(corpus.doc_tokens, doc_ids, axis=0).reshape(B, -1)
    out = jnp.concatenate([docs, query_tokens], axis=1)
    return out[:, -max_len:] if out.shape[1] > max_len else out


# --- dynamic-RAG trigger policies over generator logits --------------------


def flare_trigger(logits: jnp.ndarray, tau: float = 0.4) -> jnp.ndarray:
    """FLARE: retrieve when token confidence drops below tau. [B,V]->[B]."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return p.max(axis=-1) < tau


def dragin_trigger(logits: jnp.ndarray, attn_entropy: jnp.ndarray,
                   tau: float = 2.0) -> jnp.ndarray:
    """DRAGIN: information-need = token entropy weighted by attention
    statistics of the pending token."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    ent = -(p * jnp.log(p + 1e-9)).sum(-1)
    return ent * jnp.maximum(attn_entropy, 1e-3) > tau


def build_pipeline(corpus: Corpus, k: int, *, fused: bool = False,
                   max_len: int = 4096) -> MemoryPipeline:
    """4-stage descriptor over (memory=corpus stats, query=term ids)."""

    def prepare(M):
        return M  # corpus indexing is one-time/amortized; identity at runtime

    def relevancy(I, q):
        tfq, idf = gather_term_panel(corpus, q)
        B, D, _ = tfq.shape
        dl = jnp.broadcast_to(corpus.doc_len[None].astype(jnp.float32), (B, D))
        if fused:
            _, ids = ops.bm25_topk(tfq, dl, idf, k, block=min(4096, D),
                                   avgdl=corpus.avgdl)
            return ("fused", ids)
        return ("scores", kref.bm25_scores(tfq, dl, idf, avgdl=corpus.avgdl))

    def retrieve(M, S):
        tag, val = S
        if tag == "fused":
            return val
        _, ids = jax.lax.top_k(val, k)
        return ids

    def apply(doc_ids, q):
        return jnp.take(corpus.doc_tokens, doc_ids, axis=0)

    return MemoryPipeline(
        name="rag-fused" if fused else "rag",
        prepare=prepare, relevancy=relevancy, retrieve=retrieve, apply=apply,
        fused={"relevancy": ("relevancy", "retrieve")} if fused else {},
    )
