"""Memory-processing methods (paper Table 1). ``get_method(name)`` returns
(init_fn, make_sparse_fn) for the sparse-attention family; RAG / MemAgent /
MaC / TTT expose their own application-level APIs.
"""
from repro.core.methods import dsa, seer, lserve, rag, memagent, mac, ttt

SPARSE_METHODS = {
    "dsa": (dsa.dsa_init, dsa.make_sparse_fn),
    "seer": (seer.seer_init, seer.make_sparse_fn),
    "lserve": (lserve.lserve_init, lserve.make_sparse_fn),
}


def get_sparse_method(name: str):
    if name not in SPARSE_METHODS:
        raise KeyError(f"unknown sparse method {name!r}: {sorted(SPARSE_METHODS)}")
    return SPARSE_METHODS[name]


_METHOD_MODULES = {
    "dsa": dsa, "seer": seer, "lserve": lserve, "rag": rag,
    "memagent": memagent, "mac": mac, "ttt": ttt,
}


def offload_stages(name: str) -> tuple:
    """Which pipeline stages of ``name`` may leave the KV-owning device
    (paper §5.2): stages that read only the compressed index / documents.
    Declared per method as ``OFFLOAD_STAGES``; methods without the
    attribute (or unknown names like 'none') offload nothing."""
    mod = _METHOD_MODULES.get(name)
    return getattr(mod, "OFFLOAD_STAGES", ()) if mod else ()
