"""The paper's four-stage memory processing pipeline as a first-class,
composable abstraction (Definition 3.1 / §3.1).

  prepare(M)          -> I      index / compressed memory
  relevancy(I, x)     -> S      importance scores
  retrieve(M, S)      -> M'     selected subset / refined memory
  apply(M', x)        -> O      integrate into inference

A stage set to ``None`` is a zero-cost bypass (§3.1: "data can bypass the
stage without additional computation"). Stages may be FUSED (the paper fuses
relevancy+retrieval on the FPGA; we fuse them in one Pallas kernel) — a fused
callable occupies the earlier slot and the later slot is None, while the
profiler still attributes the fused time to both for Fig. 3-5 style
breakdowns.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax

STAGES = ("prepare", "relevancy", "retrieve", "apply")


@dataclasses.dataclass
class MemoryPipeline:
    """A concrete memory-processing method (one row of the paper's Table 1)."""

    name: str
    prepare: Optional[Callable] = None
    relevancy: Optional[Callable] = None
    retrieve: Optional[Callable] = None
    apply: Optional[Callable] = None
    # which stages each callable covers (fusion bookkeeping)
    fused: Dict[str, tuple] = dataclasses.field(default_factory=dict)

    def stages(self):
        for s in STAGES:
            fn = getattr(self, s)
            if fn is not None:
                yield s, fn, self.fused.get(s, (s,))

    def run(self, memory: Any, query: Any, profiler: "StageProfiler" = None):
        """Execute the pipeline. ``memory``/``query`` flow per Definition 3.1:
        state starts as (M, x); prepare sees M; relevancy sees (I, x);
        retrieve sees (M, S); apply sees (M', x)."""
        M, x = memory, query
        I = M
        sel = M
        out = None
        for s, fn, covers in self.stages():
            t0 = time.perf_counter() if profiler else None
            if s == "prepare":
                I = fn(M)
                res = I
            elif s == "relevancy":
                res = fn(I, x)
                sel = res
            elif s == "retrieve":
                sel = fn(M, sel)
                res = sel
            else:
                out = fn(sel, x)
                res = out
            if profiler:
                res = jax.block_until_ready(res)
                profiler.record(self.name, covers, time.perf_counter() - t0)
        return out if out is not None else sel


class StageProfiler:
    """Wall-clock stage attribution — reproduces the paper's Fig. 3-5
    methodology (fraction of latency spent in memory processing)."""

    def __init__(self):
        self.stage_seconds: Dict[str, Dict[str, float]] = {}
        self.total_seconds: Dict[str, float] = {}

    def record(self, method: str, covers: tuple, seconds: float):
        d = self.stage_seconds.setdefault(method, {s: 0.0 for s in STAGES})
        for s in covers:  # fused stages split time evenly for attribution
            d[s] += seconds / len(covers)

    def record_total(self, method: str, seconds: float):
        self.total_seconds[method] = self.total_seconds.get(method, 0.0) + seconds

    def memory_fraction(self, method: str) -> float:
        mem = sum(self.stage_seconds.get(method, {}).values())
        tot = self.total_seconds.get(method, 0.0)
        return mem / tot if tot else float("nan")

    def breakdown(self, method: str) -> Dict[str, float]:
        d = self.stage_seconds.get(method, {})
        tot = sum(d.values()) or 1.0
        return {s: v / tot for s, v in d.items()}
