"""Config system: architectures, input shapes, memory-pipeline methods.

Every assigned architecture is a frozen, hashable ``ArchConfig`` so it can be
passed as a static argument to ``jax.jit``.  Shapes are the four assigned
input-shape cells.  ``MemoryConfig`` configures the paper's four-stage memory
processing pipeline (method + hyperparameters from the paper's Appendix D).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Memory-processing pipeline configuration (the paper's contribution).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    """Hyperparameters of the four-stage memory processing pipeline.

    Defaults follow the paper's Appendix D:
      * DeepSeek Attention: 64 index heads, top-k = 2048.
      * SeerAttention-R: block size 64, token budget 4096, threshold 5e-4.
      * LServe: logical page 64, physical page = 4 logical pages.
    """

    method: str = "dsa"  # dsa | seer | lserve | mac | memagent | rag | ttt | none
    # --- DeepSeek sparse attention (lightning indexer) ---
    index_heads: int = 64
    index_dim: int = 128
    top_k: int = 2048
    # --- SeerAttention-R / LServe (block-sparse) ---
    block_size: int = 64
    token_budget: int = 4096
    threshold: float = 5e-4
    pages_per_physical: int = 4
    # --- retrieval/selection mode ---
    selection: str = "topk"  # topk | threshold
    # --- sparsity activation point: below this many cached tokens the
    #     placement policy falls back to dense attention (paper §5.2 / F). ---
    min_context: int = 4096
    # --- dynamic fallback: above this many cached tokens the paper's system
    #     falls back to the dense engine (index spills out of fast SRAM). ---
    fallback_context: int = 1 << 20

    def replace(self, **kw) -> "MemoryConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Architecture configuration.
# ---------------------------------------------------------------------------

VOCAB_PAD = 256  # Megatron-style: pad vocab to a multiple of this.


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | audio | hybrid | vlm | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_style: str = "rope"  # rope | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    sliding_window: int = 0  # 0 -> disabled
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid (Mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    shared_attn_every: int = 0  # zamba2: shared attention block period
    # xLSTM
    xlstm_pattern: str = ""  # e.g. "ms" repeated; empty -> not xlstm
    # frontends (audio/vlm): backbone consumes precomputed embeddings + tokens
    frontend: str = "none"  # none | audio_stub | vision_stub
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # memory-processing pipeline applied to this arch
    memory: MemoryConfig = dataclasses.field(default_factory=MemoryConfig)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def padded_heads(self, tp: int = 16) -> int:
        """Q heads padded to a multiple of the TP axis (Megatron dead heads)."""
        if self.n_heads % tp == 0:
            return self.n_heads
        return _round_up(self.n_heads, tp)

    def kv_shardable(self, tp: int = 16) -> bool:
        return self.n_kv_heads % tp == 0

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, VOCAB_PAD)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def ssm_groups(self) -> int:
        return 1

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.n_layers > 0 and self.d_ff == 0

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.hd
        emb = V * d * 2  # embed + lm_head
        if self.xlstm_pattern:
            per = 0
            for kind in self.xlstm_pattern:
                if kind == "m":  # mLSTM: qkv + gates + out over d_inner = 2d
                    di = 2 * d
                    per += d * di * 3 + d * di + di * d + 3 * d * di
                else:  # sLSTM: 4 gates input + recurrent + out
                    per += 4 * d * d + 4 * d * d + d * d
            return emb + per * (self.n_layers // max(len(self.xlstm_pattern), 1))
        attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        if self.n_experts:
            mlp = self.n_experts * 3 * d * ff + d * self.n_experts
        else:
            mlp = 3 * d * ff
        if self.family == "hybrid":
            di = self.d_inner
            g, N, H = self.ssm_groups, self.ssm_state, self.ssm_heads
            mamba = d * (2 * di + 2 * g * N + H) + di * d + di
            n_shared = self.n_layers // max(self.shared_attn_every, 1)
            return emb + self.n_layers * (mamba + 3 * d * ff if ff else mamba) + attn + 3 * d * ff
        return emb + self.n_layers * (attn + mlp)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dense_mlp = self.n_experts * 3 * d * ff
        active_mlp = self.experts_per_token * 3 * d * ff
        return self.n_params() - self.n_layers * (dense_mlp - active_mlp)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ArchConfig":
        """A reduced same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=512,
            head_dim=32,
        )
        if self.n_experts:
            kw.update(n_experts=4, experts_per_token=2)
        if self.family == "hybrid":
            kw.update(ssm_state=16, ssm_head_dim=32, shared_attn_every=1, n_layers=2, ssm_chunk=16)
        if self.xlstm_pattern:
            kw.update(xlstm_pattern="ms", n_layers=2, head_dim=32, n_heads=2, n_kv_heads=2)
        if self.sliding_window:
            kw.update(sliding_window=64)
        if self.rope_style == "mrope":
            hd2 = kw["head_dim"] // 2
            s1 = hd2 // 4
            s2 = (hd2 - s1) // 2
            kw.update(mrope_sections=(s1, s2, hd2 - s1 - s2))
        mem = self.memory.replace(
            index_heads=4, index_dim=32, top_k=16, token_budget=32, block_size=8,
            min_context=0,
        )
        kw["memory"] = mem
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every arch pairs with all four cells.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_shape(kind: str = "train") -> ShapeConfig:
    return {
        "train": ShapeConfig("smoke_train", 64, 2, "train"),
        "prefill": ShapeConfig("smoke_prefill", 64, 2, "prefill"),
        "decode": ShapeConfig("smoke_decode", 64, 2, "decode"),
    }[kind]
