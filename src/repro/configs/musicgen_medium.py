"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens.

The EnCodec frontend is a STUB: ``input_specs()`` provides precomputed frame
token ids over the 2048-entry codebook vocabulary; the transformer backbone is
real. 24 heads pad to 32 under 16-way TP. [arXiv:2306.05284; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    frontend="audio_stub",
    rope_theta=10000.0,
)
