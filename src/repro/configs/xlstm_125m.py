"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304 — alternating
sLSTM + mLSTM blocks (no FFN; projections live inside the blocks).

Attention-free: the memory pipeline's relevancy/retrieval stages are
inapplicable (see DESIGN.md §4); the matrix memory itself plays the
prepare/apply roles (paper's TTT row). [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    xlstm_pattern="ms",  # repeat (mLSTM, sLSTM) pairs across the 12 layers
    rope_style="none",
)
