"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.

Vocab 49155 is padded to 49408 (multiple of 256) for TP divisibility.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    n_experts=32,
    experts_per_token=8,
    rope_theta=10000.0,
)
