"""Architecture registry: ``get_arch(name)`` / ``ARCHS``."""
from repro.configs.base import (
    ArchConfig,
    MemoryConfig,
    ShapeConfig,
    SHAPES,
    smoke_shape,
)
from repro.configs.qwen3_32b import CONFIG as _qwen3_32b
from repro.configs.llama3_2_1b import CONFIG as _llama3_2_1b
from repro.configs.glm4_9b import CONFIG as _glm4_9b
from repro.configs.qwen2_7b import CONFIG as _qwen2_7b
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.qwen2_vl_72b import CONFIG as _qwen2_vl
from repro.configs.xlstm_125m import CONFIG as _xlstm

ARCHS = {
    c.name: c
    for c in [
        _qwen3_32b,
        _llama3_2_1b,
        _glm4_9b,
        _qwen2_7b,
        _granite,
        _mixtral,
        _musicgen,
        _zamba2,
        _qwen2_vl,
        _xlstm,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ArchConfig",
    "MemoryConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCHS",
    "get_arch",
    "smoke_shape",
]
