"""zamba2-7b [hybrid] — 81L d_model=3584 32H (MHA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks.

Mapping: 81 Mamba2 layers; a single weight-shared attention+MLP block is
applied after every 6th Mamba2 layer (13 applications), mirroring Zamba2's
shared-block design. The shared block owns one KV cache per application site.
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    shared_attn_every=6,
    rope_theta=10000.0,
)
