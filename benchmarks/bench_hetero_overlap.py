"""Hetero offload: overlapped vs synchronous two-phase decode (paper §5.3).

For each sparse method, serve the same pooled-decode workload through three
engine configurations:

  inline    — the PR-1 single-device engine (selection fused into the
              decode step);
  sync      — two-phase select -> apply with host barriers between phases
              (the honest serial baseline of the offload dataflow);
  overlap   — the paper's heterogeneous execution: lookahead selection on
              the offload device, double-buffered against decode.

Reported: per-step decode wall time for each configuration, the
overlap-vs-sync speedup (the paper's "memory processing hidden behind
decode" claim — overlap must not exceed sync), Fig. 3-style per-stage
fractions from the sync schedule, and the index-only exchange volumes.
Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` to give
the offload stages a real second device.

Direct invocation (CI smoke): ``python benchmarks/bench_hetero_overlap.py
--smoke``.
"""
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import bench_cfg, pick, record_result, row
from repro.hetero import HeteroProfiler
from repro.models import init_params
from repro.serving import Engine, OffloadConfig, Request, ServeConfig


REPEATS = 4


def _serve_steps(cfg, params, method, offload, *, prompt_len, steps,
                 n_slots, page):
    total = 2 + REPEATS * steps + 4         # warm-up + repeats, slots live
    sc = ServeConfig(max_len=prompt_len + total + 2 * page, n_slots=n_slots,
                     method=method, tp=4, page=page, kv_page_size=16,
                     offload_cfg=OffloadConfig(mode=offload))
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    for i in range(n_slots):
        eng.submit(Request(i, rng.integers(
            0, cfg.vocab_size, size=prompt_len).astype(np.int32), total))
    for _ in range(2):                      # compile + pipeline warm-up
        eng.poll()
    if eng.hetero is not None:                        # drop warm-up steps
        eng.hetero.profiler = HeteroProfiler(cfg, eng.mem, offload)
    reps = []
    for _ in range(pick(REPEATS, 1)):       # min over repeats: the standard
        t0 = time.perf_counter()            # low-noise estimator (shared-CPU
        for _ in range(steps):              # container jitter swamps the
            eng.step_pool()                 # ~10% select share otherwise)
        reps.append((time.perf_counter() - t0) / steps)
    return eng, float(np.min(reps))


def run():
    cfg = bench_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    prompt_len = pick(192, 32)
    steps = pick(24, 3)
    n_slots = pick(4, 2)
    for method in ("dsa", "seer", "lserve"):
        per_step = {}
        fractions = transfer = None
        for mode in ("off", "sync", "overlap"):
            eng, s = _serve_steps(cfg, params, method, mode,
                                  prompt_len=prompt_len, steps=steps,
                                  n_slots=n_slots, page=16)
            per_step[mode] = s
            if mode == "sync":
                rep = eng.hetero.report()
                fractions = rep.get("stage_fractions")
                transfer = rep.get("transfer")
            label = "inline" if mode == "off" else mode
            yield row(f"hetero_decode_{method}_{label}", s,
                      f"{n_slots}x{prompt_len}+{steps}")
        speedup = per_step["sync"] / max(per_step["overlap"], 1e-12)
        yield row(f"hetero_overlap_speedup_{method}", per_step["overlap"],
                  f"overlap_vs_sync={speedup:.2f}x")
        record_result("hetero_overlap", method, {
            "us_per_step": {m: 1e6 * s for m, s in per_step.items()},
            "tokens_per_s": {m: n_slots / s for m, s in per_step.items()},
            "overlap_vs_sync_speedup": speedup,
            "overlap_hides_select": per_step["overlap"] <= per_step["sync"],
            "stage_fractions": fractions,
            "transfer": transfer,
            "devices": jax.device_count(),
        })


if __name__ == "__main__":
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    common.set_smoke(ap.parse_args().smoke)
    for r in run():
        print(r, flush=True)
