"""Sequence-parallel apply on the main mesh (paper Fig. 6a end to end).

Two measurements over the ``ServeConfig(main_mesh=N)`` serving path:

  * per-step pooled-decode wall time with the apply phase running on a
    1- vs 2-device main mesh (bit-exactness across mesh sizes is pinned by
    tests/test_main_mesh.py — timing deltas are pure scheduling/exchange
    cost or win), standalone and composed with ``offload_shards=2``;
  * the (out, lse)-ONLY EXCHANGE INVARIANT, machine-readably: the compiled
    HLO of the LSE-merged apply is walked (``launch.hlo_walk``, trip-count
    aware) and its all-gather traffic must equal the analytic
    ``n_shards * B * Hq * (dh + 1) * 4`` bytes — independent of the view
    length S and of the selection width k, because only (out [B, Hq, dh],
    lse [B, Hq]) fp32 pairs ever cross the mesh. Raw scores would be O(S);
    KV pages would be O(k * page * KV * dh). The walk also pins all OTHER
    collective bytes at zero: nothing else crosses.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (CI's
bench-smoke does) for a real 2-device mesh; on fewer devices the mesh
clamps and the strict exchange assertion is skipped (recorded as
``mesh_devices < 2``).

Direct invocation: ``python benchmarks/bench_main_mesh.py --smoke``.
"""
import functools
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, pick, record_result, row
from repro.distributed.topk import distributed_paged_sparse_decode
from repro.launch import hlo_walk
from repro.launch.mesh import mesh_from_devices
from repro.models import init_params
from repro.serving import Engine, OffloadConfig, Request, ServeConfig

REPEATS = 3


def _exchange_bytes(mesh, B, Hq, KV, dh, S, k, ps):
    """Compiled all-gather bytes of one LSE-merged apply at (S, k)."""
    q = jnp.zeros((B, Hq, dh), jnp.float32)
    kc = jnp.zeros((B, S, KV, dh), jnp.float32)
    pids = jnp.zeros((B, k), jnp.int32)
    lens = jnp.zeros((B,), jnp.int32)
    fn = jax.jit(functools.partial(distributed_paged_sparse_decode,
                                   mesh=mesh, axis="seq", page_size=ps))
    hlo = fn.lower(q, kc, kc, pids, lens).compile().as_text()
    c = hlo_walk.walk(hlo)
    other = c.coll_bytes - c.per_collective["all-gather"]
    return c.per_collective["all-gather"], other


def _serve_steps(cfg, params, mesh_n, shards, *, prompt_len, steps, n_slots,
                 page):
    total = 2 + REPEATS * steps + 4
    sc = ServeConfig(max_len=prompt_len + total + 2 * page, n_slots=n_slots,
                     method="dsa", tp=4, page=page, kv_page_size=16,
                     offload_cfg=OffloadConfig(mode="overlap",
                                               shards=shards,
                                               main_mesh=mesh_n))
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    for i in range(n_slots):
        eng.submit(Request(i, rng.integers(
            0, cfg.vocab_size, size=prompt_len).astype(np.int32), total))
    for _ in range(2):                      # compile + pipeline warm-up
        eng.poll()
    reps = []
    for _ in range(pick(REPEATS, 1)):
        t0 = time.perf_counter()
        for _ in range(steps):
            eng.step_pool()
        reps.append((time.perf_counter() - t0) / steps)
    return eng, float(np.min(reps))


def run():
    cfg = bench_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    prompt_len = pick(192, 32)
    steps = pick(24, 3)
    n_slots = pick(4, 2)

    # -- serving wall time: mesh 1 vs 2, standalone and with 2 offload
    #    shards (4 devices: mesh {0,1}, selection shards {2,3}) ----------
    per_step = {}
    for mesh_n, shards in ((1, 1), (2, 1), (2, 2)):
        eng, s = _serve_steps(cfg, params, mesh_n, shards,
                              prompt_len=prompt_len, steps=steps,
                              n_slots=n_slots, page=16)
        per_step[(mesh_n, shards)] = s
        rep = eng.hetero.report()
        mesh_devs = rep["devices"].get("main_mesh", [])
        yield row(f"main_mesh_decode_mesh{mesh_n}_shards{shards}", s,
                  f"{n_slots}x{prompt_len}+{steps},"
                  f"mesh_devices={len(set(mesh_devs)) or 1}")
        record_result("main_mesh", f"dsa_mesh{mesh_n}_shards{shards}", {
            "us_per_step": 1e6 * s,
            "tokens_per_s": n_slots / s,
            "main_mesh": mesh_n,
            "offload_shards": shards,
            "devices": jax.device_count(),
            "mesh_devices": len(set(mesh_devs)) or 1,
            "vs_mesh1_speedup": per_step[(1, 1)] / s,
        })

    # -- (out, lse)-only exchange: all-gather bytes equal the analytic
    #    formula and DO NOT move with S or k ---------------------------
    n_mesh = min(2, jax.device_count())
    mesh = mesh_from_devices(jax.devices()[:n_mesh], ("seq",))
    B, Hq, KV, dh, ps = 2, cfg.n_heads, cfg.n_kv_heads, cfg.hd, 16
    expect = n_mesh * B * Hq * (dh + 1) * 4       # (out, lse) fp32 pairs
    grid = {}
    for S in (pick(2048, 256), pick(4096, 512)):
        for k in (4, 16):
            ag, other = _exchange_bytes(mesh, B, Hq, KV, dh, S, k, ps)
            grid[f"S{S}_k{k}"] = {"all_gather_bytes": ag,
                                  "other_collective_bytes": other}
    ags = {v["all_gather_bytes"] for v in grid.values()}
    others = {v["other_collective_bytes"] for v in grid.values()}
    exchange_ok = (n_mesh < 2) or (ags == {expect} and others == {0.0})
    if n_mesh >= 2:
        assert exchange_ok, (grid, expect)
    record_result("main_mesh", "exchange_out_lse_only", {
        "mesh_devices": n_mesh,
        "expected_bytes": expect,
        "independent_of_S_and_k": len(ags) == 1,
        "exchange_ok": exchange_ok,
        "grid": grid,
    })
    yield row("main_mesh_exchange_bytes", 0.0,
              f"allgather={max(ags):.0f}B,expect={expect}B,"
              f"ok={exchange_ok}")
    yield row("main_mesh_scaling", per_step[(2, 2)],
              f"mesh1={1e6 * per_step[(1, 1)]:.0f}us,"
              f"mesh2+shards2={1e6 * per_step[(2, 2)]:.0f}us")


if __name__ == "__main__":
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    common.set_smoke(ap.parse_args().smoke)
    for r in run():
        print(r, flush=True)
