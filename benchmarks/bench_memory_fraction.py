"""Paper Fig. 3/4/5: fraction of step latency spent in memory processing.

Two complementary measurements:
  * MEASURED (CPU, small bench model): wall-clock stage attribution via the
    StageProfiler over growing context — the trend (fraction grows with
    context) is the paper's Fig. 3 claim.
  * DERIVED (target TPU, full-size archs): analytic stage costs
    (placement.StageCost) at 4K / 64K / 1M context — reproduces the paper's
    "1-11% at 4K -> 22-81% at 1M" band check.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, pick, row, timeit
from repro.core import placement
from repro.core.methods import dsa, get_sparse_method
from repro.core.pipeline import StageProfiler
from repro.models import init_params, prefill, decode_step


def run():
    rows = []
    cfg = bench_cfg()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, tp=4)
    init_fn, mk = get_sparse_method("dsa")
    sp_all = init_fn(key, cfg, cfg.memory)
    sfn = mk(cfg, cfg.memory, tp=4, page=16)

    mem = cfg.memory
    page = 16
    n_sel = max(mem.top_k // page, 1)
    for S in pick((512, 2048), (256,)):
        toks = jax.random.randint(key, (2, S), 0, cfg.vocab_size)
        _, caches = jax.jit(lambda p, t: prefill(p, cfg, t, max_len=S, tp=4))(
            params, toks)
        sparse = jax.jit(lambda p, t, c, s: decode_step(
            p, cfg, t, c, tp=4, sparse_fn=sfn, sparse_params=s)[0])
        t_total = timeit(sparse, params, toks[:, 0], caches, sp_all)
        # jitted per-stage timings on one layer's cache, scaled by L
        sp0 = jax.tree.map(lambda a: a[0], sp_all)
        q = jax.random.normal(key, (2, 1, cfg.padded_heads(4), cfg.hd))
        kc, vc = caches["k"][0], caches["v"][0]
        B = kc.shape[0]

        @jax.jit
        def stage_prepare(kc):
            k_idx = kc.reshape(B, S, -1) @ sp0["wk_idx"]
            return k_idx.reshape(B, S // page, page, -1).mean(axis=2)

        kp = stage_prepare(kc)

        @jax.jit
        def stage_rel_ret(q, kp):
            from repro.kernels import ref as kref
            qf = q[:, 0].reshape(B, -1)[:, : sp0["wq_idx"].shape[0]]
            q_idx = (qf @ sp0["wq_idx"]).reshape(B, -1, sp0["wk_idx"].shape[1])
            w = jax.nn.softmax(qf.astype(jnp.float32) @ sp0["w_wgt"], -1)
            sc = kref.relevancy_scores(q_idx, kp, w)
            return jax.lax.top_k(sc, n_sel)[1]

        pidx = stage_rel_ret(q, kp)

        @jax.jit
        def stage_apply(q, kc, vc, pidx):
            from repro.kernels import ops as kops
            length = jnp.full((B,), S, jnp.int32)
            return kops.paged_decode_attention(
                q[:, 0, : cfg.n_heads], kc, vc, pidx.astype(jnp.int32),
                length, page_size=page)[0]

        t_stage = (timeit(stage_prepare, kc)
                   + timeit(stage_rel_ret, q, kp)
                   + timeit(stage_apply, q, kc, vc, pidx))
        t_mem = t_stage * cfg.n_layers
        frac = min(t_mem / t_total, 1.0)
        rows.append(row(f"fig3_measured_ctx{S}_memfrac", t_total,
                        f"frac={frac:.2f}"))

    # derived for the assigned full-size archs (target-hardware roofline)
    for arch in ("qwen3-32b", "llama3.2-1b", "qwen2-vl-72b"):
        from repro.configs import get_arch
        acfg = get_arch(arch)
        for ctx in (4096, 65536, 1 << 20):
            c = placement.sparse_attention_stage_costs(acfg, acfg.memory, ctx)
            mem_s = sum(v.seconds() for k, v in c.items() if k != "rest")
            tot_s = mem_s + c["rest"].seconds()
            rows.append(row(f"fig3_derived_{arch}_ctx{ctx}", tot_s,
                            f"memfrac={mem_s / tot_s:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
