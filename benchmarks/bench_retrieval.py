"""Serving-integrated retrieval: trigger-to-splice latency + decode impact.

For dynamic RAG (and a MaC flavor), serve the same pooled-decode workload
with always-firing FLARE triggers through the three retrieval schedules:

  inline   — service on the main device, resolved at the trigger step
             (the stop-retrieve-resume baseline);
  sync     — service on the offload device, serialized (what moving the
             corpus off the generator costs without overlap);
  overlap  — the subsystem's point: the corpus/bank scoring runs on the
             retrieval device UNDER the other slots' decode step.

Reported per mode: mean trigger-to-splice wall latency, per-step decode
wall time, tokens/s, and the exchange ledger (query/ids vs doc-span
bytes). Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=2``
to give the retrieval stages a real second device.

Direct invocation (CI smoke): ``python benchmarks/bench_retrieval.py
--smoke``.
"""
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import bench_cfg, pick, record_result, row
from repro.core.methods.mac import MacConfig
from repro.data import build_corpus
from repro.models import init_params
from repro.retrieval import RetrievalConfig
from repro.serving import Engine, Request, ServeConfig


def _serve(cfg, params, corpus, kind, mode, *, prompt_len, steps, n_slots):
    kw = dict(kind=kind, mode=mode, trigger="flare", tau=1.1,
              min_interval=pick(8, 1), max_retrievals=4, query_window=8)
    if kind == "rag":
        kw.update(corpus=corpus, k=2)
    else:
        kw.update(mac=MacConfig(segment_len=16, memory_slots=8,
                                retrieve_k=2))
    sc = ServeConfig(max_len=prompt_len + steps + 96, n_slots=n_slots,
                     method="none", tp=4, kv_page_size=16,
                     retrieval=RetrievalConfig(**kw))
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    for i in range(n_slots):
        eng.submit(Request(i, rng.integers(
            0, cfg.vocab_size, size=prompt_len).astype(np.int32), steps))
    for _ in range(2):                       # compile warm-up
        eng.poll()
    t0 = time.perf_counter()
    emitted, hops = 0, 0
    while emitted < n_slots * steps and hops < 40 * steps:
        emitted += len(eng.poll())
        hops += 1
    wall = time.perf_counter() - t0
    return eng, wall / max(hops, 1), emitted / max(wall, 1e-9)


def run():
    cfg = bench_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    corpus = build_corpus(pick(4096, 128), retrieval_vocab=512,
                          doc_max=16, gen_vocab=cfg.vocab_size, seed=0)
    prompt_len = pick(96, 24)
    steps = pick(24, 6)
    n_slots = pick(4, 2)
    for kind in ("rag", "mac"):
        lat = {}
        for mode in ("inline", "sync", "overlap"):
            eng, per_step, tps = _serve(cfg, params, corpus, kind, mode,
                                        prompt_len=prompt_len, steps=steps,
                                        n_slots=n_slots)
            rep = eng.retrieval.report()
            lat[mode] = rep["trigger_to_splice_s"]["mean"]
            yield row(f"retrieval_{kind}_{mode}", per_step,
                      f"trig2splice={1e6 * lat[mode]:.0f}us "
                      f"n={rep['retrievals']}")
            record_result("retrieval", f"{kind}_{mode}", {
                "us_per_step": 1e6 * per_step,
                "tokens_per_s": tps,
                "trigger_to_splice_us": 1e6 * lat[mode],
                "retrievals": rep["retrievals"],
                "spliced_tokens": rep["spliced_tokens"],
                "transfer": rep["transfer"],
                "devices": jax.device_count(),
            })
        yield row(f"retrieval_{kind}_overlap_vs_sync", lat["overlap"],
                  f"latency_ratio={lat['overlap'] / max(lat['sync'], 1e-12):.2f}x")


if __name__ == "__main__":
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    common.set_smoke(ap.parse_args().smoke)
    for r in run():
        print(r, flush=True)
