"""Paper Table 3: energy per request/token — DERIVED from the roofline time
model and the chip power model (placement.py). The container is CPU-only so
energy is modeled, not measured (DESIGN.md §7); the paper's qualitative
claim under test: the accelerated pipeline reduces J/tok, and the reduction
grows with context until the fallback point.
"""
from benchmarks.common import row
from repro.configs import get_arch
from repro.core import placement


def run():
    rows = []
    for arch in ("qwen3-32b", "llama3.2-1b"):
        cfg = get_arch(arch)
        for ctx in (65536, 1 << 20):
            c = placement.sparse_attention_stage_costs(cfg, cfg.memory, ctx)
            # accelerated: fused pipeline time x its (mostly memory-bound) power
            t_pipe = sum(v.seconds() for k, v in c.items() if k != "rest")
            t_rest = c["rest"].seconds()
            e_fast = sum(v.seconds() * v.watts() for v in c.values())
            # baseline: dense decode attention instead of the pipeline
            dense = placement.dense_decode_cost(cfg, ctx)
            e_base = (dense.seconds() * dense.watts()
                      + t_rest * c["rest"].watts())
            t_base = dense.seconds() + t_rest
            rows.append(row(
                f"table3_{arch}_ctx{ctx}", t_pipe + t_rest,
                f"J/tok={e_fast * cfg.n_layers:.4f};baseJ={e_base * cfg.n_layers:.4f};"
                f"improve={e_base / e_fast:.2f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
