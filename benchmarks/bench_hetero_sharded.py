"""Sharded hetero offload: per-shard offload devices + index-only merge
(paper §5.2 / Fig. 6a at scale).

Serves the same pooled-decode workload through the hetero executor with a
growing number of KV-sequence shards on the offload side (1 = the PR-2
single-device executor, 2/4 = ``ShardedHeteroExecutor`` with one summary
shard per device) and reports:

  * per-step decode wall time per topology (sharding must not change
    tokens — bit-exactness is pinned by tests/test_hetero_sharded.py —
    so any delta is pure scheduling/transfer cost or win);
  * the INDEX-ONLY INVARIANT, machine-readably: every shard's up link
    moves k (val, idx) candidate pairs per step — 8 bytes per candidate —
    which must stay below the bytes of ONE KV page (what a page-shipping
    design would move per selected page, per layer);
  * per-shard down/up traffic from the per-shard TransferLedgers.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (CI's
bench-smoke does) to give shards real devices: main + one per shard at
shards=2, round-robin above that.

Direct invocation: ``python benchmarks/bench_hetero_sharded.py --smoke``.
"""
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import bench_cfg, pick, record_result, row
from repro.models import init_params
from repro.serving import Engine, OffloadConfig, Request, ServeConfig

REPEATS = 3


def _serve_steps(cfg, params, shards, *, prompt_len, steps, n_slots, page):
    total = 2 + REPEATS * steps + 4
    sc = ServeConfig(max_len=prompt_len + total + 2 * page, n_slots=n_slots,
                     method="dsa", tp=4, page=page, kv_page_size=16,
                     offload_cfg=OffloadConfig(mode="overlap",
                                               shards=shards))
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    for i in range(n_slots):
        eng.submit(Request(i, rng.integers(
            0, cfg.vocab_size, size=prompt_len).astype(np.int32), total))
    for _ in range(2):                      # compile + pipeline warm-up
        eng.poll()
    reps = []
    for _ in range(pick(REPEATS, 1)):
        t0 = time.perf_counter()
        for _ in range(steps):
            eng.step_pool()
        reps.append((time.perf_counter() - t0) / steps)
    return eng, float(np.min(reps))


def run():
    cfg = bench_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    prompt_len = pick(192, 32)
    steps = pick(24, 3)
    n_slots = pick(4, 2)
    # one KV page on the interconnect: page_size tokens * KV heads * head
    # dim * bf16 * (K and V) — the unit the index-only exchange must beat
    kv_page_bytes = 16 * cfg.n_kv_heads * cfg.hd * 2 * 2
    per_step = {}
    for shards in (1, 2, 4):
        eng, s = _serve_steps(cfg, params, shards, prompt_len=prompt_len,
                              steps=steps, n_slots=n_slots, page=16)
        per_step[shards] = s
        hx = eng.hetero
        rep = hx.report()
        if shards == 1:
            ledgers = [hx.ledger]
            n_part = hx.sel.n_sel
        else:
            ledgers = hx.ledgers
            n_part = rep["shards"]["candidates_per_shard"]
        up_per_step = [led.up_bytes / max(led.steps, 1) for led in ledgers]
        index_only_ok = all(u < kv_page_bytes for u in up_per_step)
        yield row(f"hetero_sharded_decode_shards{shards}", s,
                  f"{n_slots}x{prompt_len}+{steps},"
                  f"up_B/step/shard={max(up_per_step):.0f}")
        record_result("hetero_sharded", f"dsa_shards{shards}", {
            "us_per_step": 1e6 * s,
            "tokens_per_s": n_slots / s,
            "shards": shards,
            "devices": jax.device_count(),
            "distinct_offload_devices":
                rep["shards"]["distinct_offload_devices"]
                if shards > 1 else int(rep["devices"]["distinct"]),
            "candidates_per_shard": n_part,
            "per_shard_up_bytes_per_step": up_per_step,
            "kv_page_bytes": kv_page_bytes,
            "index_only_ok": index_only_ok,
            "vs_shards1_speedup": per_step[1] / s,
            "transfer": rep["transfer"],
        })
    yield row("hetero_sharded_scaling", per_step[max(per_step)],
              f"shards1={1e6 * per_step[1]:.0f}us,"
              f"shards4={1e6 * per_step[4]:.0f}us")


if __name__ == "__main__":
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    common.set_smoke(ap.parse_args().smoke)
    for r in run():
        print(r, flush=True)
