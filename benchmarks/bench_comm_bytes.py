"""Paper Appendix C.1 / Fig. 16: cross-engine communication vs computation.

The paper's PCIe measurement becomes an ICI measurement: from the cached
dry-run artifacts, compare the bytes the distributed memory pipeline
exchanges (index-only: 8B * k * shards) against (a) what a naive KV
all-gather would move and (b) the end-to-end step's collective volume —
reproducing the "three orders of magnitude" headroom claim.
"""
import glob
import json
import os

from benchmarks.common import row
from repro.configs import SHAPES, get_arch
from repro.core.placement import ICI_BW

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def run():
    rows = []
    for arch in ("qwen3-32b", "qwen2-vl-72b", "llama3.2-1b"):
        cfg = get_arch(arch)
        for shape_name in ("decode_32k", "long_500k"):
            shape = SHAPES[shape_name]
            shards = 16 if shape_name == "decode_32k" else 256
            k = cfg.memory.top_k
            idx_bytes = 8 * k * shards              # (score, index) pairs
            kv_bytes = (shape.seq_len * cfg.n_kv_heads * cfg.hd * 2 * 2
                        * shape.global_batch)       # full KV all-gather
            rows.append(row(
                f"appC_{arch}_{shape_name}_indexonly",
                idx_bytes / ICI_BW,
                f"bytes={idx_bytes};kv_allgather_bytes={kv_bytes};"
                f"ratio={kv_bytes / idx_bytes:.0f}x"))
            f = os.path.join(
                DRYRUN, f"{arch}__{shape_name}__16x16__baseline.json")
            if os.path.exists(f):
                rec = json.load(open(f))
                if rec.get("ok"):
                    coll = rec["roofline"]["coll_bytes_per_dev"]
                    rows.append(row(
                        f"appC_{arch}_{shape_name}_step_collectives",
                        coll / ICI_BW, f"bytes_per_dev={coll:.3e}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
