"""Benchmark orchestrator — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (stdout) and writes the
structured payloads modules deposit via ``common.record_result`` to
``--out`` (default ``BENCH_PR9.json``) at the repo root (method, tokens/s,
per-stage fractions, ...) AND to the stable ``BENCH.json`` "latest" alias,
so the perf trajectory is diffable across PRs from one canonical filename
(the per-PR path used to be hardcoded, which left every later PR's
trajectory empty).

``--smoke``: tiny configs and single iterations (run in CI so benchmark code
can't silently rot). Smoke numbers are execution proofs, not measurements.
``--only SUBSTR``: run only benches whose label contains SUBSTR.
"""
import argparse
import json
import os
import sys
import time
import traceback

# allow both `python benchmarks/run.py` and `python -m benchmarks.run`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import common
from benchmarks import (bench_memory_fraction, bench_kernel_speedup,
                        bench_e2e, bench_energy, bench_batch_scaling,
                        bench_comm_bytes, bench_hetero_overlap,
                        bench_hetero_sharded, bench_retrieval,
                        bench_main_mesh, bench_fused_decode, bench_router)

BENCHES = [
    ("memory_fraction (Fig 3/4/5)", bench_memory_fraction),
    ("kernel_speedup (Fig 9/10r)", bench_kernel_speedup),
    ("e2e_speedup (Fig 8/10l/11/12)", bench_e2e),
    ("energy (Table 3)", bench_energy),
    ("batch_scaling (Table 4)", bench_batch_scaling),
    ("comm_bytes (App C.1/Fig 16)", bench_comm_bytes),
    ("hetero_overlap (§5.3 offload)", bench_hetero_overlap),
    ("hetero_sharded (Fig 6a per-shard offload)", bench_hetero_sharded),
    ("retrieval (dynamic RAG/MaC service)", bench_retrieval),
    ("main_mesh (Fig 6a seq-parallel apply)", bench_main_mesh),
    ("fused_decode (multi-step scan windows)", bench_fused_decode),
    ("router (fleet serving, Poisson load)", bench_router),
]

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_PR9.json")
LATEST = os.path.join(ROOT, "BENCH.json")   # stable cross-PR alias


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs, 1 iteration (CI execution check)")
    ap.add_argument("--only", default="",
                    help="run only benches whose label contains this")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="structured-results path; when it is the default "
                         "per-PR artifact the stable BENCH.json latest "
                         "alias is refreshed alongside it (a scratch --out "
                         "leaves the committed alias untouched)")
    args = ap.parse_args()
    common.set_smoke(args.smoke)
    print("name,us_per_call,derived")
    failures = 0
    rows = []
    for label, mod in BENCHES:
        if args.only and args.only not in label:
            continue
        t0 = time.time()
        try:
            for r in mod.run():
                rows.append(r)
                print(r, flush=True)
            print(f"# {label}: done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {label}: FAILED\n# " +
                  traceback.format_exc().replace("\n", "\n# "), flush=True)
    payload = {"smoke": common.is_smoke(), "results": common.results(),
               "rows": rows}
    if (args.only or failures) and os.path.exists(args.out):
        # partial or partially-failed run: refresh the sections + rows that
        # actually ran; keep the rest of the committed cross-PR artifact
        # intact (every results payload carries its own "smoke" stamp from
        # common.record_result)
        with open(args.out) as f:
            old = json.load(f)
        old.setdefault("results", {}).update(payload["results"])
        by_name = {r.split(",", 1)[0]: r for r in rows}
        old["rows"] = [by_name.pop(r.split(",", 1)[0], r)
                       for r in old.get("rows", [])] + list(by_name.values())
        payload = old
    paths = [args.out]
    if os.path.abspath(args.out) == os.path.abspath(DEFAULT_OUT):
        paths.append(LATEST)     # the alias tracks the canonical artifact
    for path in paths:
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {path}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
