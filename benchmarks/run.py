"""Benchmark orchestrator — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (stdout).

``--smoke``: tiny configs and single iterations (run in CI so benchmark code
can't silently rot). Smoke numbers are execution proofs, not measurements.
"""
import argparse
import os
import sys
import time
import traceback

# allow both `python benchmarks/run.py` and `python -m benchmarks.run`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import common
from benchmarks import (bench_memory_fraction, bench_kernel_speedup,
                        bench_e2e, bench_energy, bench_batch_scaling,
                        bench_comm_bytes)

BENCHES = [
    ("memory_fraction (Fig 3/4/5)", bench_memory_fraction),
    ("kernel_speedup (Fig 9/10r)", bench_kernel_speedup),
    ("e2e_speedup (Fig 8/10l/11/12)", bench_e2e),
    ("energy (Table 3)", bench_energy),
    ("batch_scaling (Table 4)", bench_batch_scaling),
    ("comm_bytes (App C.1/Fig 16)", bench_comm_bytes),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs, 1 iteration (CI execution check)")
    args = ap.parse_args()
    common.set_smoke(args.smoke)
    print("name,us_per_call,derived")
    failures = 0
    for label, mod in BENCHES:
        t0 = time.time()
        try:
            for r in mod.run():
                print(r, flush=True)
            print(f"# {label}: done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {label}: FAILED\n# " +
                  traceback.format_exc().replace("\n", "\n# "), flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
