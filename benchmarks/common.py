"""Shared benchmark utilities: timing, CSV rows, small bench configs."""
import sys
import os
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

# --smoke mode (benchmarks/run.py --smoke, exercised in CI): tiny shapes and
# single iterations so the benchmark code paths stay executable without the
# full measurement cost. Numbers produced under smoke are NOT comparable.
_SMOKE = {"on": False}


def set_smoke(flag: bool) -> None:
    _SMOKE["on"] = bool(flag)


def is_smoke() -> bool:
    return _SMOKE["on"]


def pick(full, smoke):
    """Select the full-run or smoke-run variant of a benchmark parameter."""
    return smoke if _SMOKE["on"] else full


# Machine-readable results registry: benchmark modules deposit structured
# payloads here and run.py serializes everything to BENCH_PR<N>.json at the
# repo root so the perf trajectory is diffable across PRs. Each payload is
# stamped with the mode it was measured under so a partial refresh
# (run.py --only) can never pass smoke numbers off as full-run ones.
_RESULTS = {}


def record_result(section: str, name: str, payload) -> None:
    if isinstance(payload, dict):
        payload = dict(payload, smoke=is_smoke())
    _RESULTS.setdefault(section, {})[name] = payload


def results() -> dict:
    return _RESULTS


def timeit(fn, *args, warmup=2, iters=5):
    """Median wall time of fn(*args) in seconds (block_until_ready)."""
    if _SMOKE["on"]:
        warmup, iters = min(warmup, 1), 1
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def bench_cfg(n_layers=4, seq=1024):
    """Small-but-representative CPU bench model."""
    from repro.configs import get_arch
    return get_arch("llama3.2-1b").replace(
        name="bench-llama", n_layers=n_layers, d_model=256, n_heads=8,
        n_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=2048,
        memory=get_arch("llama3.2-1b").memory.replace(
            index_heads=8, index_dim=32, top_k=256, token_budget=256,
            block_size=16, min_context=0),
    )
