"""Shared benchmark utilities: timing, CSV rows, small bench configs."""
import sys
import os
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def timeit(fn, *args, warmup=2, iters=5):
    """Median wall time of fn(*args) in seconds (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def bench_cfg(n_layers=4, seq=1024):
    """Small-but-representative CPU bench model."""
    from repro.configs import get_arch
    return get_arch("llama3.2-1b").replace(
        name="bench-llama", n_layers=n_layers, d_model=256, n_heads=8,
        n_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=2048,
        memory=get_arch("llama3.2-1b").memory.replace(
            index_heads=8, index_dim=32, top_k=256, token_budget=256,
            block_size=16, min_context=0),
    )
