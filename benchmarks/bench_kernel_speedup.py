"""Paper Fig. 9 / Fig. 10(right): speedup of the fused relevancy+retrieval
engine over the unfused baseline.

  * MEASURED (CPU): unfused XLA pipeline (full scores materialized -> top-k)
    vs the fused candidate scheme (jitted, use_pallas(False) so both sides
    are XLA — an apples-to-apples algorithmic comparison).
  * DERIVED (TPU roofline): byte-traffic model — the fused kernel streams
    keys once and never writes scores to HBM; the unfused path writes+reads
    the score vector. speedup = bytes_unfused / bytes_fused at 819 GB/s.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import pick, row, timeit
from repro.kernels import ops, ref


def run():
    # run.py keeps going after a failed bench, so the use_pallas(False)
    # below must be undone even on exceptions — later benches in the same
    # process (e2e, batch_scaling, hetero_overlap) need the kernels back.
    prev = ops.pallas_enabled()
    try:
        return _run()
    finally:
        ops.use_pallas(prev)


def _run():
    rows = []
    rng = np.random.default_rng(0)
    B, Hq, dk, k = 1, 64, 128, 2048

    for S in pick((8192, 65536), (2048,)):
        q = jnp.asarray(rng.standard_normal((B, Hq, dk)), jnp.bfloat16)
        keys = jnp.asarray(rng.standard_normal((B, S, dk)), jnp.bfloat16)
        w = jnp.abs(jnp.asarray(rng.standard_normal((B, Hq)), jnp.float32))

        unfused = jax.jit(lambda q, kk, w: ref.relevancy_topk(q, kk, w, k))
        # route the "fused" side through the jitted XLA reference too (CPU
        # interpret-mode Pallas would swamp the comparison); run() restores.
        ops.use_pallas(False)
        fused = jax.jit(lambda q, kk, w: ops.relevancy_topk(q, kk, w, k,
                                                            block=4096))
        t_u = timeit(unfused, q, keys, w)
        t_f = timeit(fused, q, keys, w)
        rows.append(row(f"fig9_measured_S{S}_unfused", t_u, ""))
        rows.append(row(f"fig9_measured_S{S}_fused", t_f,
                        f"speedup={t_u / t_f:.2f}"))
        # derived roofline speedup on TPU v5e
        bytes_keys = S * dk * 2
        bytes_scores = S * 4
        unfused_bytes = bytes_keys + 2 * bytes_scores  # write + re-read scores
        fused_bytes = bytes_keys + k * 8               # only candidates leave
        rows.append(row(f"fig9_derived_S{S}", unfused_bytes / 819e9,
                        f"speedup={unfused_bytes / fused_bytes:.2f}"))

    # BM25 (Fig. 10 right): fused vs unfused over the doc panel
    D, T, kk = pick(16384, 2048), 16, 64
    tf = jnp.asarray(rng.poisson(1.0, (1, D, T)), jnp.float32)
    dl = jnp.asarray(rng.integers(20, 200, (1, D)), jnp.float32)
    idf = jnp.asarray(rng.random((1, T)), jnp.float32)
    unf = jax.jit(lambda a, b, c: ref.bm25_topk(a, b, c, kk))
    fus = jax.jit(lambda a, b, c: ops.bm25_topk(a, b, c, kk, block=4096))
    t_u = timeit(unf, tf, dl, idf)
    t_f = timeit(fus, tf, dl, idf)
    rows.append(row(f"fig10_bm25_D{D}_unfused", t_u, ""))
    rows.append(row(f"fig10_bm25_D{D}_fused", t_f,
                    f"speedup={t_u / t_f:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
