"""Paper Table 4: speedup of the accelerated pipeline vs batch size.

Paper claim: for sparse attention the speedup GROWS with batch size (dense
components amortize weights; the memory-bound pipeline does not), while
MemAgent-style full-decode offload DEGRADES with batch. Measured on the CPU
bench model (trend) + derived roofline ratios.

Second section: pooled serving throughput, old vs new. The OLD path is the
legacy dense ``n_slots x max_len`` pool whose decode runs at the shared
``lengths.max()`` watermark over ``max_len``; the NEW path is the paged pool
with per-slot lengths and a pow2-bucketed decode view sized by the longest
LIVE sequence. Same requests, same batch — the report is tokens/s for each.
"""
import numpy as np

import jax

from benchmarks.common import bench_cfg, pick, row, timeit
from repro.core.methods import get_sparse_method
from repro.models import init_params, prefill, decode_step


def run():
    rows = []
    cfg = bench_cfg(n_layers=2)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, tp=4)
    S = pick(2048, 256)
    init_fn, mk = get_sparse_method("dsa")
    sp = init_fn(key, cfg, cfg.memory)
    sfn = mk(cfg, cfg.memory, tp=4, page=16)

    for B in pick((1, 2, 4, 8), (1, 2)):
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        _, caches = jax.jit(lambda p, t: prefill(p, cfg, t, max_len=S, tp=4))(
            params, toks)
        dense = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c, tp=4)[0])
        sparse = jax.jit(lambda p, t, c, s: decode_step(
            p, cfg, t, c, tp=4, sparse_fn=sfn, sparse_params=s)[0])
        t_d = timeit(dense, params, toks[:, 0], caches, iters=3)
        t_s = timeit(sparse, params, toks[:, 0], caches, sp, iters=3)
        rows.append(row(f"table4_dsa_B{B}", t_s,
                        f"speedup={t_d / t_s:.2f}"))

    rows.extend(_pooled_serving_rows(cfg, params))
    return rows


def _pooled_serving_rows(cfg, params):
    """Tokens/s of the pooled decode loop: legacy watermark vs paged."""
    import time

    from repro.serving import Engine, Request, ServeConfig

    rows = []
    rng = np.random.default_rng(0)
    max_len = pick(1024, 256)
    prompt_len = pick(128, 32)
    steps = pick(64, 4)
    for B in pick((2, 4, 8), (2,)):
        tps = {}
        for paged in (False, True):
            eng = Engine(cfg, params,
                         ServeConfig(max_len=max_len, n_slots=B,
                                     method="none", tp=4, paged=paged,
                                     kv_page_size=16))
            for i in range(B):
                eng.submit(Request(
                    i, rng.integers(0, cfg.vocab_size, size=prompt_len),
                    max_len - prompt_len))
            eng.poll()   # admit + compile + first step outside timing
            t0 = time.perf_counter()
            n_tok = 0
            for _ in range(steps):
                n_tok += len(eng.step_pool())
            jax.block_until_ready(
                eng.pool.device["k_pages"] if paged else eng.caches["k"])
            dt = time.perf_counter() - t0
            tag = "paged" if paged else "watermark"
            tps[tag] = n_tok / max(dt, 1e-9)
            rows.append(row(f"table4_pooled_{tag}_B{B}", dt / max(steps, 1),
                            f"tok_s={tps[tag]:.1f}"))
        rows.append(row(
            f"table4_pooled_speedup_B{B}", 0.0,
            f"paged_vs_watermark={tps['paged'] / max(tps['watermark'], 1e-9):.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
