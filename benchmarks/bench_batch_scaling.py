"""Paper Table 4: speedup of the accelerated pipeline vs batch size.

Paper claim: for sparse attention the speedup GROWS with batch size (dense
components amortize weights; the memory-bound pipeline does not), while
MemAgent-style full-decode offload DEGRADES with batch. Measured on the CPU
bench model (trend) + derived roofline ratios.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import bench_cfg, row, timeit
from repro.core.methods import get_sparse_method
from repro.models import init_params, prefill, decode_step


def run():
    rows = []
    cfg = bench_cfg(n_layers=2)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, tp=4)
    S = 2048
    init_fn, mk = get_sparse_method("dsa")
    sp = init_fn(key, cfg, cfg.memory)
    sfn = mk(cfg, cfg.memory, tp=4, page=16)

    for B in (1, 2, 4, 8):
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        _, caches = jax.jit(lambda p, t: prefill(p, cfg, t, max_len=S, tp=4))(
            params, toks)
        dense = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c, tp=4)[0])
        sparse = jax.jit(lambda p, t, c, s: decode_step(
            p, cfg, t, c, tp=4, sparse_fn=sfn, sparse_params=s)[0])
        t_d = timeit(dense, params, toks[:, 0], caches, iters=3)
        t_s = timeit(sparse, params, toks[:, 0], caches, sp, iters=3)
        rows.append(row(f"table4_dsa_B{B}", t_s,
                        f"speedup={t_d / t_s:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
