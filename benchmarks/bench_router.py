"""Fleet-scale serving under open-loop load: Poisson arrivals -> Router.

The paper frames memory processing as a SERVING cost (22%-97% of request
latency at fleet scale), so the router is measured the way serving systems
are: an open-loop arrival process (Poisson inter-arrival gaps, so queueing
delay is real — requests arrive whether or not the fleet is ready), a
mixed population (dense / sparse-method pins / retrieval opt-ins, short
and long prompts, sticky sessions), and tail-latency metrics:

  * TTFT p50 / p99   submit -> first emitted token (queueing + admission
                     prefill + first decode dispatch)
  * per-token p50/p99  mean inter-token gap of each finished stream
  * queue depth      per-replica admission-queue depth over the run
  * utilization      per-replica mean fraction of slots decoding

Full mode serves a 4-method fleet (none/dsa/seer/lserve, one replica
each); ``--smoke`` serves none+dsa. Results go to ``record_result
("router", ...)`` -> BENCH_PR9.json; CI asserts the smoke payload's TTFT
quantiles are present and non-degenerate.
"""
import time

import jax
import numpy as np

from benchmarks.common import bench_cfg, is_smoke, pick, record_result, row
from repro.data import build_corpus
from repro.models import init_params
from repro.retrieval import RetrievalConfig
from repro.serving import Request, Router, ServeConfig


def _fleet(cfg, params, corpus):
    methods = pick(("none", "dsa", "seer", "lserve"), ("none", "dsa"))
    rcfg = RetrievalConfig(kind="rag", mode="sync", corpus=corpus, k=2,
                           trigger="flare", tau=1.1, min_interval=4,
                           max_retrievals=2, query_window=6)
    cfgs = [ServeConfig(max_len=pick(512, 128), n_slots=pick(4, 2),
                        method=m, tp=4, page=16, kv_page_size=16,
                        retrieval=rcfg)
            for m in methods]
    return Router.build(cfg, params, cfgs,
                        key=jax.random.PRNGKey(0)), methods


def _schedule(cfg, methods, *, n_reqs, rate_hz, max_new, seed=0):
    """Poisson arrival offsets (seconds) + the mixed request population."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_reqs))
    lens = rng.choice(pick((32, 96, 192), (12, 24)), size=n_reqs)
    out = []
    for i in range(n_reqs):
        overrides = None
        if rng.random() < 0.5:      # half the traffic pins a method
            overrides = {"method": str(rng.choice(methods))}
        session = f"s{rng.integers(4)}" if rng.random() < 0.33 else None
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(lens[i])).astype(np.int32)
        out.append((float(arrivals[i]),
                    Request(i, prompt, max_new,
                            retrieval=bool(rng.random() < 0.25),
                            method_overrides=overrides, session=session)))
    return out


def _drive(router, schedule, max_polls=20_000):
    """Open-loop: submit each request AT its arrival time (sleeping through
    idle gaps, never early), poll the fleet between arrivals."""
    handles, i = [], 0
    t0 = time.perf_counter()
    while (i < len(schedule) or router.busy()) and max_polls:
        now = time.perf_counter() - t0
        while i < len(schedule) and schedule[i][0] <= now:
            handles.append(router.submit(schedule[i][1]))
            i += 1
        if i < len(schedule) and not router.busy():
            time.sleep(max(0.0, schedule[i][0] - now))
            continue
        router.poll()
        max_polls -= 1
    router.drain()
    return handles, time.perf_counter() - t0


def _quantiles(xs):
    xs = np.asarray([x for x in xs if x is not None], np.float64)
    if not xs.size:
        return None
    return {"p50": float(np.quantile(xs, 0.50)),
            "p99": float(np.quantile(xs, 0.99)),
            "mean": float(xs.mean()), "n": int(xs.size)}


def run():
    cfg = bench_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    corpus = build_corpus(pick(1024, 64), retrieval_vocab=256, doc_max=8,
                          gen_vocab=cfg.vocab_size, embed_dim=16, seed=0)
    router, methods = _fleet(cfg, params, corpus)
    n_reqs = pick(48, 8)
    max_new = pick(24, 5)
    rate_hz = pick(12.0, 60.0)    # smoke: a burst, so queueing still shows
    sched = _schedule(cfg, methods, n_reqs=n_reqs, rate_hz=rate_hz,
                      max_new=max_new, seed=1)

    # compile warm-up outside the measured run: one tiny request per
    # replica (pinned), drained before the clock starts
    warm = [Request(-1 - r.index,
                    np.arange(8, dtype=np.int32) % cfg.vocab_size, 2,
                    method_overrides={"method": r.method})
            for r in router.replicas]
    for w in warm:
        router.submit(w)
    router.drain()
    for w in warm:
        for r in router.replicas:
            r.engine.done.pop(w.rid, None)
            r.engine._handles.pop(w.rid, None)

    handles, wall = _drive(router, sched)
    done = {h.rid: h for h in handles if h.done}
    assert len(done) == n_reqs, f"only {len(done)}/{n_reqs} finished"

    ttft = _quantiles([h.ttft_s() for h in done.values()])
    ptok = _quantiles([h.per_token_s() for h in done.values()])
    rep = router.report()
    n_tok = sum(len(h.tokens) for h in done.values())
    payload = {
        "fleet": list(methods),
        "n_requests": n_reqs,
        "rate_hz": rate_hz,
        "max_new": max_new,
        "wall_s": wall,
        "tokens_per_s": n_tok / max(wall, 1e-9),
        "ttft_s": ttft,
        "per_token_s": ptok,
        "sessions": rep["sessions"],
        "replicas": [
            {"replica": r["replica"], "method": r["method"],
             "utilization": r["utilization"],
             "queue_depth": r["queue_depth"], "done": r["done"],
             "devices": r["devices"]}
            for r in rep["replicas"]],
        "shared_corpus": rep.get("shared_corpus"),
    }
    record_result("router", f"poisson_{len(methods)}x", payload)

    rows = [
        row(f"router_{len(methods)}x_ttft_p50", ttft["p50"],
            f"p99={ttft['p99'] * 1e6:.0f}us n={n_reqs}"),
        row(f"router_{len(methods)}x_per_token_p50",
            ptok["p50"] if ptok else 0.0,
            f"tok_s={payload['tokens_per_s']:.1f}"),
    ]
    for r in rep["replicas"]:
        rows.append(row(
            f"router_util_r{r['replica']}_{r['method']}", 0.0,
            f"util={r['utilization']:.2f} "
            f"qmax={r['queue_depth']['max']}"))
    if is_smoke():
        assert ttft and ttft["p50"] > 0 and ttft["p99"] >= ttft["p50"]
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    common.set_smoke(args.smoke)
    print("\n".join(run()))
