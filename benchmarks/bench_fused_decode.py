"""Fused multi-step decode: host-dispatch amortization of the serving loop.

The stepped serving loop pays one Python round-trip per decoded token —
launch the jitted decode, pull logits to host, argmax, update the slot
table, launch again. ``ServeConfig(fused_steps=K)`` folds K steps into one
``lax.scan`` dispatch (serving/fused.py); this benchmark measures what that
buys on the same pooled workload:

  * per-step decode wall time for K in {1, 8, 32}, inline pipeline and the
    hetero overlap pipeline (where the fused window also runs the lookahead
    double-buffer on device);
  * host transitions per decoded step (``stats.host_steps /
    stats.decode_steps``) — the dispatch amortization itself, which is the
    schedule-level claim and holds even when kernel time dominates on this
    CPU container;
  * an in-bench assertion that fused K=8 consumed no more than
    ceil(steps / 8) host dispatches — windows only break early for slot
    completions/triggers, and this workload has none mid-run.

Direct invocation (CI smoke): ``python benchmarks/bench_fused_decode.py
--smoke``.
"""
import math
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import bench_cfg, pick, record_result, row
from repro.models import init_params
from repro.serving import Engine, OffloadConfig, Request, ServeConfig

REPEATS = 4
FUSED_KS = (1, 8, 32)


def _serve(cfg, params, offload, K, *, prompt_len, steps, n_slots):
    sc = ServeConfig(max_len=2048, n_slots=n_slots, method="dsa", tp=4,
                     page=16, kv_page_size=16,
                     offload_cfg=OffloadConfig(mode=offload),
                     fused_steps=K)
    eng = Engine(cfg, params, sc, key=jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    budget = 2 * K + REPEATS * steps + 64   # stay live through all repeats
    for i in range(n_slots):
        eng.submit(Request(i, rng.integers(
            0, cfg.vocab_size, size=prompt_len).astype(np.int32), budget))
    done = 0
    while done < 2 * K:                     # compile + pipeline warm-up
        done += max(1, eng.poll().steps)
    eng.stats["host_steps"] = eng.stats["decode_steps"] = 0
    reps = []
    for _ in range(pick(REPEATS, 1)):
        done = 0
        t0 = time.perf_counter()
        while done < steps:
            done += max(1, eng.step_pool().steps)
        reps.append((time.perf_counter() - t0) / done)
    return eng, float(np.min(reps))


def run():
    cfg = bench_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    prompt_len = pick(192, 32)
    steps = pick(32, 8)
    n_slots = pick(4, 2)
    out = []
    for offload in ("off", "overlap"):
        per_k = {}
        for K in FUSED_KS:
            eng, s = _serve(cfg, params, offload, K,
                            prompt_len=prompt_len, steps=steps,
                            n_slots=n_slots)
            hs, ds = eng.stats["host_steps"], eng.stats["decode_steps"]
            transitions = hs / max(ds, 1)
            if K == 8:
                # windows break only for completions/triggers; this
                # workload has none mid-run, so K=8 must amortize fully
                assert hs <= math.ceil(ds / 8), (hs, ds)
            per_k[K] = {"us_per_step": 1e6 * s,
                        "host_steps": hs, "decode_steps": ds,
                        "host_transitions_per_step": transitions}
            out.append(row(f"fused_decode/{offload}/K={K}", s,
                           f"host_transitions={transitions:.3f}"))
        amort = (per_k[1]["host_transitions_per_step"]
                 / max(per_k[8]["host_transitions_per_step"], 1e-9))
        record_result("fused_decode", offload, {
            "method": "dsa", "offload": offload, "per_k": per_k,
            "dispatch_amortization_k8": amort,
            "speedup_k8_vs_k1": per_k[1]["us_per_step"]
            / max(per_k[8]["us_per_step"], 1e-9),
            "host_transitions_ok": True,
        })
    return out


if __name__ == "__main__":
    from benchmarks.common import set_smoke
    set_smoke("--smoke" in sys.argv)
    for r in run():
        print(r)
