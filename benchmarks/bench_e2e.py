"""Paper Fig. 8 / 10(left) / 11 / 12: end-to-end speedup of the accelerated
memory pipeline over the dense baseline, measured on the CPU bench model.

  * sparse-attention decode (DSA/Seer/LServe) vs dense decode at growing
    context (Fig. 8 trend: speedup grows with context),
  * Memory-as-Context with fused query-gen + cross-attn vs unfused (Fig. 11),
  * MemAgent prefill/decode disaggregation accounting (Fig. 12):
    prefill-vs-decode time split that motivates role separation.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, pick, row, timeit
from repro.core.methods import get_sparse_method, mac
from repro.models import init_params, prefill, decode_step


def run():
    rows = []
    cfg = bench_cfg()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, tp=4)

    for S in pick((512, 2048, 4096), (256,)):
        toks = jax.random.randint(key, (2, S), 0, cfg.vocab_size)
        _, caches = jax.jit(lambda p, t: prefill(p, cfg, t, max_len=S, tp=4))(
            params, toks)
        dense = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c, tp=4)[0])
        t_dense = timeit(dense, params, toks[:, 0], caches)
        for method in ("dsa", "seer", "lserve"):
            init_fn, mk = get_sparse_method(method)
            sp = init_fn(key, cfg, cfg.memory)
            kw = {"page": 16} if method == "dsa" else {}
            sfn = mk(cfg, cfg.memory, tp=4, **kw)
            sparse = jax.jit(lambda p, t, c, s: decode_step(
                p, cfg, t, c, tp=4, sparse_fn=sfn, sparse_params=s)[0])
            t_sp = timeit(sparse, params, toks[:, 0], caches, sp)
            rows.append(row(f"fig8_{method}_ctx{S}", t_sp,
                            f"e2e_speedup={t_dense / t_sp:.2f}"))

    # Fig 11: MaC — top-k retrieval pipeline vs attending the FULL memory
    # bank (no retrieval): the pipeline shrinks the backbone's context from
    # memory_slots to retrieve_k extra positions.
    mc = mac.MacConfig(segment_len=pick(256, 64),
                       memory_slots=pick(64, 16), retrieve_k=4)
    mp = mac.mac_init(key, cfg)
    bank = mac.bank_init(cfg, mc, batch=2)
    for _ in range(mc.memory_slots):
        bank = mac.push(bank, jnp.ones((2, cfg.d_model)))
    seg_toks = jax.random.randint(key, (2, mc.segment_len), 0, cfg.vocab_size)
    from repro.models import layers as ML

    def run_with_context(p, b, t, extra):
        emb = ML.embed(p["embed"], t)
        if extra == mc.retrieve_k:
            ctx, _ = mac.segment_step(mp, b, emb, mc)
        else:  # no retrieval: prepend the whole bank
            ctx = jnp.concatenate([b["bank"].astype(emb.dtype), emb], axis=1)
        from repro.models.model import forward
        h, _, _ = forward(p, cfg, jnp.zeros((2, ctx.shape[1]), jnp.int32),
                          tp=4)
        return h

    t_ret = timeit(jax.jit(lambda p, b, t: run_with_context(p, b, t,
                                                            mc.retrieve_k)),
                   params, bank, seg_toks, iters=3)
    t_full = timeit(jax.jit(lambda p, b, t: run_with_context(p, b, t,
                                                             mc.memory_slots)),
                    params, bank, seg_toks, iters=3)
    rows.append(row("fig11_mac_retrieval", t_ret,
                    f"speedup_vs_full_bank={t_full / t_ret:.2f}"))

    # Fig 12: MemAgent prefill vs decode time per segment (role split)
    seg = pick(256, 64)
    n_dec = pick(32, 8)
    seg_toks = jax.random.randint(key, (2, seg), 0, cfg.vocab_size)
    pf = jax.jit(lambda p, t: prefill(p, cfg, t, max_len=seg + 32, tp=4))
    t_prefill = timeit(pf, params, seg_toks)
    _, c0 = pf(params, seg_toks)
    dec = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c, tp=4))

    def decode32(p, c):
        tok = jnp.zeros((2,), jnp.int32)
        for _ in range(n_dec):
            logits, c = dec(p, tok, c)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return tok

    t_decode = timeit(decode32, params, c0, iters=3)
    rows.append(row("fig12_memagent_prefill_per_seg", t_prefill, ""))
    rows.append(row("fig12_memagent_decode32_per_seg", t_decode,
                    f"decode_share={t_decode / (t_decode + t_prefill):.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
