"""End-to-end serving driver (the paper's kind: INFERENCE): a small model
serving batched requests through the continuous-batching scheduler, with the
memory-processing pipeline as a first-class feature — compare methods:

    PYTHONPATH=src python examples/serve_sparse_attention.py \
        --method dsa --requests 12 --prompt-len 48 --max-new 16

Methods: none (dense baseline) | dsa | seer | lserve. The engine's traced
lax.cond implements the paper's dynamic fallback (dense below min_context /
above fallback_context).

``--offload on`` routes the memory-processing stages through the hetero
subsystem (overlapped lookahead selection on a second device — start with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` for a real split)
and prints the per-stage overhead breakdown from its profiler.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import init_params
from repro.serving import Engine, OffloadConfig, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--method", default="dsa",
                    choices=["none", "dsa", "seer", "lserve"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--offload", default="off",
                    choices=["on", "off", "sync", "overlap"],
                    help="hetero offload executor (on = overlap)")
    args = ap.parse_args()
    from repro.hetero import resolve_cli_offload
    try:
        offload = resolve_cli_offload(args.offload, args.method)
    except ValueError as e:
        ap.error(str(e))

    cfg = get_arch(args.arch).smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    eng = Engine(cfg, params,
                 ServeConfig(max_len=args.prompt_len + args.max_new + 16,
                             n_slots=args.slots, method=args.method, tp=4,
                             page=8,
                             offload_cfg=OffloadConfig(mode=offload)),
                 key=jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    handles = [eng.submit(Request(
        i, rng.integers(0, cfg.vocab_size, size=args.prompt_len),
        args.max_new)) for i in range(args.requests)]
    done = eng.drain()
    wall = time.perf_counter() - t0
    toks = sum(len(h.tokens) for h in handles)
    ttft = [h.ttft_s() for h in handles if h.ttft_s() is not None]
    lat = [h.finished - h.submitted for h in handles if h.done]
    print(f"method={args.method} offload={offload} "
          f"completed={len(done)}/{args.requests} tokens={toks}")
    print(f"wall={wall:.2f}s throughput={toks / wall:.1f} tok/s "
          f"p50_ttft={np.median(ttft):.2f}s "
          f"p50_latency={np.median(lat):.2f}s p95={np.quantile(lat, .95):.2f}s")
    print(f"slot utilization={eng.slots.utilization():.2f}")
    if eng.hetero is not None:
        print("hetero per-stage breakdown (Fig. 3 style):")
        print(json.dumps(eng.hetero.report(), indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
