"""Quickstart: build a small model, train briefly, serve with the
memory-processing pipeline (DSA sparse attention) — the 60-second tour.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import TokenStream
from repro.models import init_params
from repro.serving import Engine, ServeConfig
from repro.train import OptConfig, TrainConfig, Trainer


def main():
    # 1) an assigned architecture, reduced for CPU
    cfg = get_arch("llama3.2-1b").smoke()
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size} (padded {cfg.padded_vocab})")

    # 2) train a few steps (loss must drop on the structured synthetic data)
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    tr = Trainer(cfg, TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=5,
                                                total_steps=100), tp=4),
                 params)
    ds = TokenStream(cfg.vocab_size, 64, 4, seed=0)
    for i, batch in zip(range(20), ds):
        stats = tr.train_step({k: jnp.asarray(v) for k, v in batch.items()})
        if i % 5 == 0:
            print(f"step {i:3d} loss {stats['loss']:.3f} "
                  f"lr {stats['lr']:.2e} |g| {stats['grad_norm']:.2f}")

    # 3) serve with the paper's memory pipeline (DeepSeek-style sparse
    #    attention with dynamic dense fallback below min_context)
    eng = Engine(cfg, tr.params,
                 ServeConfig(max_len=128, n_slots=4, method="dsa", tp=4,
                             page=8),
                 key=jax.random.PRNGKey(1))
    prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                 cfg.vocab_size)
    out = eng.generate(prompts, max_new=8)
    print("generated tokens:\n", out)
    print(f"prefill {eng.stats['prefill_s']*1e3:.1f}ms, "
          f"decode {eng.stats['decode_s']*1e3:.1f}ms "
          f"({eng.stats['tokens']} tokens)")


if __name__ == "__main__":
    main()
