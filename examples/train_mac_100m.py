"""Train a Memory-as-Context (Titans/HMT-style) model: the backbone consumes
[retrieved memory embeddings; segment], then pushes a compressed segment
summary into the FIFO memory bank (paper Table 1 row 8, Fig. 6c).

Default config is CPU-sized; ``--full`` selects the ~100M-parameter setup
(d=768, 12L) for a few hundred steps on real hardware.

    PYTHONPATH=src python examples/train_mac_100m.py --steps 30
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.methods import mac
from repro.data import TokenStream
from repro.models import layers as L
from repro.models import model as M
from repro.train import OptConfig, adamw_update, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (slow on CPU)")
    ap.add_argument("--segments", type=int, default=2)
    args = ap.parse_args()

    base = get_arch("llama3.2-1b")
    if args.full:
        cfg = base.replace(name="mac-100m", n_layers=12, d_model=768,
                           n_heads=12, n_kv_heads=12, head_dim=64,
                           d_ff=3072, vocab_size=32000)
        seg_len, B = 256, 4
    else:
        cfg = base.smoke()
        seg_len, B = 32, 2
    mc = mac.MacConfig(segment_len=seg_len, memory_slots=16, retrieve_k=2)

    key = jax.random.PRNGKey(0)
    params = {"backbone": M.init_params(cfg, key, tp=4),
              "mac": mac.mac_init(jax.random.PRNGKey(1), cfg)}
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params / 1e6:.1f}M  segments/step: {args.segments}")

    def loss_fn(p, tokens, labels):
        bank = mac.bank_init(cfg, mc, B)
        total = jnp.zeros(())
        for s in range(args.segments):
            seg = jax.lax.dynamic_slice_in_dim(tokens, s * seg_len, seg_len, 1)
            lab = jax.lax.dynamic_slice_in_dim(labels, s * seg_len, seg_len, 1)
            emb = L.embed(p["backbone"]["embed"], seg)
            ctx, _ = mac.segment_step(p["mac"], bank, emb, mc)
            # run the backbone on [memory; segment] (embeds injected)
            h, _, _ = M.forward(p["backbone"], cfg,
                                jnp.zeros(ctx.shape[:2], jnp.int32),
                                img_embeds=ctx, tp=4)
            h_seg = h[:, mc.retrieve_k:]
            logits = L.lm_head(p["backbone"]["lm_head"], h_seg, cfg)
            total += L.cross_entropy(logits, lab)
            bank = mac.push(bank, mac.prepare_memory(p["mac"], h_seg))
        return total / args.segments

    opt = init_opt_state(params)
    oc = OptConfig(lr=3e-3, warmup_steps=5, total_steps=max(args.steps, 10))
    step = jax.jit(lambda p, o, t, l: (
        lambda lg: (adamw_update(lg[1], o, p, oc), lg[0]))(
        jax.value_and_grad(loss_fn)(p, t, l)))

    ds = TokenStream(cfg.vocab_size, seg_len * args.segments, B, seed=0)
    first = last = None
    for i, batch in zip(range(args.steps), ds):
        (params, opt, stats), loss = step(params, opt,
                                          jnp.asarray(batch["tokens"]),
                                          jnp.asarray(batch["labels"]))
        last = float(loss)
        first = first if first is not None else last
        if i % 5 == 0:
            print(f"step {i:4d} loss {last:.3f}")
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'OK' if last < first else 'no improvement'})")


if __name__ == "__main__":
    main()
