"""Dynamic RAG through the serving-integrated retrieval subsystem.

The corpus lives in a ``RetrievalService`` (the retrieval engine): fused
BM25 scoring runs on the device hosting the index, documents are appended
incrementally without re-jitting, and at serve time per-slot FLARE triggers
splice retrieved documents into the paged KV pool mid-decode — overlapped
against the other slots' decode steps.

    PYTHONPATH=src python examples/rag_pipeline.py --docs 2048
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python examples/rag_pipeline.py --mode overlap
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.methods import rag
from repro.data import build_corpus, sample_queries
from repro.models import init_params
from repro.retrieval import RetrievalConfig, RetrievalService
from repro.serving import Request, Router, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--mode", default="overlap",
                    choices=["inline", "sync", "overlap"])
    args = ap.parse_args()

    cfg = get_arch("llama3.2-1b").smoke()

    # --- the document-memory service: fused BM25 on the hosting device ---
    half = args.docs // 2
    corpus = build_corpus(args.docs, retrieval_vocab=1024, doc_max=16,
                          gen_vocab=cfg.vocab_size, embed_dim=32, seed=0)
    svc = RetrievalService(rag.corpus_slice(corpus, 0, half), k=args.k)
    q_terms = np.asarray(sample_queries(corpus, args.batch, 8, seed=1))
    t0 = time.perf_counter()
    ids, spans = svc.collect(svc.query(q_terms))
    print(f"service: {svc.n_docs} docs, top-{args.k} in "
          f"{time.perf_counter() - t0:.3f}s; top ids {ids[:, 0]}")

    # --- incremental ingest: the second half appends without re-jitting ---
    t0 = time.perf_counter()
    svc.ingest(rag.corpus_slice(corpus, half, args.docs))
    ids2, _ = svc.collect(svc.query(q_terms))
    print(f"ingest +{args.docs - half} docs in {time.perf_counter()-t0:.3f}s "
          f"-> {svc.n_docs} docs; top ids now {ids2[:, 0]}")

    # --- two-stage first pass: hybrid BM25+embedding scoring on-store ---
    q_emb = np.ones((args.batch, 32), np.float32) / np.sqrt(32)
    _, cand = svc.query_hybrid(q_terms, q_emb, n_first=16)
    print(f"hybrid first-pass candidates: {np.asarray(cand[:, :4])}...")

    # --- serve time: a 2-replica fleet sharing THIS service; per-slot
    # FLARE triggers splice docs mid-decode on whichever replica serves ---
    params = init_params(cfg, jax.random.PRNGKey(0), tp=4)
    rcfg = RetrievalConfig(kind="rag", mode=args.mode, corpus=corpus,
                           k=2, trigger="flare", tau=0.9,
                           min_interval=4, max_retrievals=2,
                           service=svc)       # fleet-shared corpus
    sc = ServeConfig(max_len=256, n_slots=args.batch, method="none",
                     tp=4, retrieval=rcfg)
    router = Router.build(cfg, params, sc, n_replicas=2,
                          key=jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    handles = [router.submit(Request(
        i, rng.integers(0, cfg.vocab_size, size=24), 16, retrieval=True,
        session=f"user{i % 2}")) for i in range(args.batch)]
    done = router.drain()
    wall = time.perf_counter() - t0
    toks = sum(len(h.tokens) for h in handles)
    n_ret = sum(r.engine.retrieval.report()["retrievals"]
                for r in router.replicas)
    rep = router.report()
    print(f"fleet of {rep['n_replicas']} replicas served {len(done)} "
          f"requests ({toks} tokens) in {wall:.2f}s, mode={args.mode}: "
          f"{n_ret} retrievals from the shared "
          f"{rep['shared_corpus']['n_docs']}-doc corpus, "
          f"mean TTFT {1e3 * rep['ttft_s']['mean']:.1f}ms, placements "
          f"{[h.replica for h in handles]}")


if __name__ == "__main__":
    main()
