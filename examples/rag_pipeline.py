"""RAG memory-processing pipeline over a synthetic Zipf corpus: single-stage
BM25 (DRAGIN/FLARE/FS-RAG style, fused Pallas score+top-k) and two-stage
hybrid retrieval + cross-encoder reranking (paper Table 1 rows 4-6), with
dynamic retrieval triggers over generator logits.

    PYTHONPATH=src python examples/rag_pipeline.py --docs 2048
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.methods import rag
from repro.data import build_corpus, sample_queries
from repro.models import init_params, layers as L, model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--k", type=int, default=8)
    args = ap.parse_args()

    corpus = build_corpus(args.docs, retrieval_vocab=1024, doc_max=32,
                          gen_vocab=512, embed_dim=32, seed=0)
    print(f"corpus: {corpus.n_docs} docs, avgdl={corpus.avgdl:.1f}")
    q_terms = sample_queries(corpus, args.batch, 8, seed=1)

    # --- single-stage BM25 (fused kernel) ---
    t0 = time.perf_counter()
    scores, ids = rag.bm25_retrieve(corpus, q_terms, k=args.k, fused=True)
    jax.block_until_ready(ids)
    print(f"single-stage BM25: top-{args.k} in {time.perf_counter()-t0:.3f}s; "
          f"top doc ids {np.asarray(ids[:, 0])}")

    # --- two-stage: hybrid first pass + tiny cross-encoder reranker ---
    cfg = get_arch("llama3.2-1b").smoke()
    reranker = init_params(cfg, jax.random.PRNGKey(3), tp=4)

    def score_fn(query_tokens, docs):
        B, N, D = docs.shape
        pairs = jnp.concatenate(
            [jnp.repeat(query_tokens[:, None], N, 1), docs], axis=-1)
        flat = pairs.reshape(B * N, -1) % cfg.vocab_size
        h, _, _ = M.forward(reranker, cfg, flat, tp=4)
        pooled = h.mean(axis=1).astype(jnp.float32)
        return (pooled @ reranker["lm_head"]["w"][:, 0].astype(
            jnp.float32)).reshape(B, N)

    q_emb = jnp.ones((args.batch, 32), jnp.float32) / np.sqrt(32)
    t0 = time.perf_counter()
    _, cand = rag.hybrid_retrieve(corpus, q_terms, q_emb, n_first=32)
    top, ids2 = rag.rerank(jax.jit(score_fn), corpus, q_terms, cand, k=args.k)
    jax.block_until_ready(ids2)
    print(f"two-stage (hybrid + reranker): {time.perf_counter()-t0:.3f}s; "
          f"reranked ids {np.asarray(ids2[:, 0])}")

    # --- apply-to-inference: append docs, prefill the generator ---
    query_tokens = (q_terms % cfg.vocab_size).astype(jnp.int32)
    augmented = rag.append_to_query(corpus, query_tokens, ids[:, :2],
                                    max_len=128)
    gen = init_params(cfg, jax.random.PRNGKey(4), tp=4)
    logits, _ = jax.jit(lambda p, t: M.prefill(p, cfg, t, tp=4))(
        gen, augmented % cfg.vocab_size)
    # dynamic triggers decide whether to retrieve again (DRAGIN/FLARE)
    flare = rag.flare_trigger(logits, tau=0.4)
    print(f"augmented prompt len={augmented.shape[1]}, "
          f"FLARE would re-retrieve for {int(flare.sum())}/{args.batch} seqs")


if __name__ == "__main__":
    main()
